//! Instrumentation: kernel-launch counters, phase timers, table printing.
//!
//! The paper's evaluation (§6) reports per-phase runtimes (spatial data
//! structure, tree traversal, batched ACA, batched dense mat-vec, …). The
//! global [`Recorder`] collects those phases; benches drain it to print the
//! same series the paper plots. Span-level (nested, per-thread) timing and
//! histogram quantiles live in [`crate::obs`]; [`timed`] feeds both layers
//! at once.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

static KERNEL_LAUNCHES: AtomicU64 = AtomicU64::new(0);
static VIRTUAL_THREADS: AtomicU64 = AtomicU64::new(0);

/// Record one BSP kernel launch of `n` virtual threads.
#[inline]
pub fn count_launch(n: usize) {
    KERNEL_LAUNCHES.fetch_add(1, Ordering::Relaxed);
    VIRTUAL_THREADS.fetch_add(n as u64, Ordering::Relaxed);
}

/// (launches, virtual threads) since process start.
pub fn launch_stats() -> (u64, u64) {
    (KERNEL_LAUNCHES.load(Ordering::Relaxed), VIRTUAL_THREADS.load(Ordering::Relaxed))
}

/// Accumulator shards per recorder: enough that the handful of batcher
/// executor + client threads rarely collide on one lock.
const NSHARDS: usize = 16;

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread is pinned to one shard index for its lifetime, so a
    /// thread's `add`s never contend with other threads mapped elsewhere.
    static SHARD: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % NSHARDS;
}

/// A named wall-clock phase accumulator, sharded per thread.
///
/// Hot paths (`add`/`incr` from concurrent batcher clients and executor
/// threads) lock only their own thread's shard; reads (`stats`, `stat`,
/// `count`, `total`) merge all shards, so the public API is unchanged from
/// the old single-map recorder while writes no longer serialize globally.
pub struct Recorder {
    shards: [Mutex<HashMap<String, (Duration, u64)>>; NSHARDS],
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())) }
    }

    pub fn add(&self, phase: &str, d: Duration) {
        let shard = SHARD.with(|s| *s);
        let mut m = self.shards[shard].lock().unwrap();
        let e = m.entry(phase.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Time `f` under `phase`.
    pub fn time<T>(&self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    /// Count an event under `phase` without timing it (zero-duration add).
    /// Event counters (`runtime.matmat_fallback`, `governor.evict`, …)
    /// surface through the count column of [`Recorder::stats`] and
    /// `hmx phases` next to the timed phases.
    pub fn incr(&self, phase: &str) {
        self.add(phase, Duration::ZERO);
    }

    /// Merged `(total, count)` for one phase across all shards.
    fn merged(&self, phase: &str) -> (Duration, u64) {
        let mut total = Duration::ZERO;
        let mut count = 0;
        for shard in &self.shards {
            if let Some(&(d, c)) = shard.lock().unwrap().get(phase) {
                total += d;
                count += c;
            }
        }
        (total, count)
    }

    /// Total event/call count recorded under `phase` (zero if never seen).
    pub fn count(&self, phase: &str) -> u64 {
        self.merged(phase).1
    }

    /// Total accumulated duration for `phase` (zero if never recorded).
    pub fn total(&self, phase: &str) -> Duration {
        self.merged(phase).0
    }

    /// Snapshot of `(phase, total, count)` sorted by total descending.
    /// Prefer [`Recorder::stats`], which correlates counts and mean
    /// durations per phase instead of leaving that to the caller.
    pub fn snapshot(&self) -> Vec<(String, Duration, u64)> {
        self.stats().into_iter().map(|s| (s.phase, s.total, s.count)).collect()
    }

    /// Aggregate view with total, call count and mean duration together
    /// per phase, merged across shards and sorted by total descending.
    pub fn stats(&self) -> Vec<PhaseStats> {
        let mut merged: HashMap<String, (Duration, u64)> = HashMap::new();
        for shard in &self.shards {
            for (k, &(d, c)) in shard.lock().unwrap().iter() {
                let e = merged.entry(k.clone()).or_insert((Duration::ZERO, 0));
                e.0 += d;
                e.1 += c;
            }
        }
        let mut v: Vec<PhaseStats> =
            merged.into_iter().map(|(k, (d, c))| PhaseStats::new(k, d, c)).collect();
        v.sort_by(|a, b| b.total.cmp(&a.total));
        v
    }

    /// Stats for a single phase, if it has been recorded.
    pub fn stat(&self, phase: &str) -> Option<PhaseStats> {
        let (d, c) = self.merged(phase);
        if c == 0 && d == Duration::ZERO {
            None
        } else {
            Some(PhaseStats::new(phase.to_string(), d, c))
        }
    }

    pub fn reset(&self) {
        for shard in &self.shards {
            shard.lock().unwrap().clear();
        }
    }
}

/// One phase's aggregate: total, call count and mean duration correlated
/// in a single record (previously callers had to divide totals by counts
/// by hand). The serving batcher reports its wait/apply latencies through
/// these.
#[derive(Clone, Debug)]
pub struct PhaseStats {
    pub phase: String,
    pub total: Duration,
    pub count: u64,
    pub mean: Duration,
}

impl PhaseStats {
    fn new(phase: String, total: Duration, count: u64) -> Self {
        let mean = if count == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos((total.as_nanos() / count as u128) as u64)
        };
        PhaseStats { phase, total, count, mean }
    }
}

/// Global phase recorder used by the H-matrix pipeline.
pub static RECORDER: once_cell::sync::Lazy<Recorder> =
    once_cell::sync::Lazy::new(Recorder::new);

/// Convenience: time a closure under the global recorder, and open a
/// tracing span of the same name so enabled traces get the construction
/// and matvec phase timeline with no extra instrumentation at call sites.
pub fn timed<T>(phase: &str, f: impl FnOnce() -> T) -> T {
    let _span = crate::obs::span(phase);
    RECORDER.time(phase, f)
}

/// Median-of-`trials` wall-clock measurement of `f` (paper: averaged over
/// five trials; we report the median, which is robust on shared machines,
/// and the mean alongside).
pub fn measure<T>(trials: usize, mut f: impl FnMut() -> T) -> Measurement {
    assert!(trials >= 1);
    let mut samples = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    Measurement { median, mean, min: samples[0], max: *samples.last().unwrap(), trials }
}

#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub trials: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Print a CSV header + row helper used by every bench binary so output is
/// uniform and grep-able (`hmx-bench` prefix).
///
/// Header emission is guarded by a [`std::sync::Once`]: exactly one header
/// per table instance, from whichever thread prints first. `Once` is also
/// what makes the type `Sync`, so a table can be shared across worker
/// threads or held in a `static` — the old `Cell<bool>` guard was neither
/// thread-safe nor `Sync`, and rows emitted from multiple threads could
/// each print their own header.
pub struct CsvTable {
    name: &'static str,
    columns: &'static [&'static str],
    header: std::sync::Once,
}

impl CsvTable {
    pub const fn new(name: &'static str, columns: &'static [&'static str]) -> Self {
        CsvTable { name, columns, header: std::sync::Once::new() }
    }

    /// The header line the first time it is called on this instance,
    /// `None` on every later call (from any thread).
    pub fn header_row(&self) -> Option<String> {
        let mut out = None;
        self.header
            .call_once(|| out = Some(format!("hmx-bench,{},{}", self.name, self.columns.join(","))));
        out
    }

    pub fn row(&self, values: &[String]) {
        if let Some(h) = self.header_row() {
            println!("{h}");
        }
        assert_eq!(values.len(), self.columns.len());
        println!("hmx-bench,{},{}", self.name, values.join(","));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_accumulates() {
        let r = Recorder::new();
        r.add("x", Duration::from_millis(2));
        r.add("x", Duration::from_millis(3));
        assert_eq!(r.total("x"), Duration::from_millis(5));
        let snap = r.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].2, 2);
    }

    #[test]
    fn stats_correlate_counts_and_means() {
        let r = Recorder::new();
        r.add("apply", Duration::from_millis(6));
        r.add("apply", Duration::from_millis(2));
        r.add("wait", Duration::from_millis(1));
        let stats = r.stats();
        assert_eq!(stats.len(), 2);
        // sorted by total descending
        assert_eq!(stats[0].phase, "apply");
        assert_eq!(stats[0].count, 2);
        assert_eq!(stats[0].total, Duration::from_millis(8));
        assert_eq!(stats[0].mean, Duration::from_millis(4));
        let w = r.stat("wait").unwrap();
        assert_eq!(w.count, 1);
        assert_eq!(w.mean, Duration::from_millis(1));
        assert!(r.stat("missing").is_none());
    }

    #[test]
    fn incr_counts_events_without_time() {
        let r = Recorder::new();
        assert_eq!(r.count("evt"), 0);
        r.incr("evt");
        r.incr("evt");
        assert_eq!(r.count("evt"), 2);
        assert_eq!(r.total("evt"), Duration::ZERO);
        let s = r.stat("evt").unwrap();
        assert_eq!(s.count, 2);
        assert_eq!(s.mean, Duration::ZERO);
    }

    #[test]
    fn sharded_adds_merge_across_threads() {
        static R: once_cell::sync::Lazy<Recorder> = once_cell::sync::Lazy::new(Recorder::new);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    for _ in 0..100 {
                        R.add("sharded.phase", Duration::from_micros(10));
                        R.incr("sharded.event");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(R.count("sharded.phase"), 800);
        assert_eq!(R.total("sharded.phase"), Duration::from_micros(8000));
        assert_eq!(R.count("sharded.event"), 800);
        let s = R.stat("sharded.phase").unwrap();
        assert_eq!(s.mean, Duration::from_micros(10));
    }

    #[test]
    fn measure_returns_ordered_stats() {
        let m = measure(5, || std::thread::sleep(Duration::from_micros(50)));
        assert!(m.min <= m.median && m.median <= m.max);
        assert_eq!(m.trials, 5);
    }

    #[test]
    fn launch_counter_monotone() {
        let (l0, t0) = launch_stats();
        count_launch(10);
        let (l1, t1) = launch_stats();
        assert!(l1 > l0 && t1 >= t0 + 10);
    }

    #[test]
    fn csv_header_prints_once_across_threads() {
        static TABLE: CsvTable = CsvTable::new("hdr_test", &["a", "b"]);
        let headers: usize = (0..8)
            .map(|_| std::thread::spawn(|| TABLE.header_row().is_some() as usize))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum();
        assert_eq!(headers, 1, "exactly one thread gets the header");
        assert!(TABLE.header_row().is_none());
    }
}
