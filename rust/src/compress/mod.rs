//! Operator-wide compression governor: budgeted global rank truncation,
//! mixed-precision factor storage, and memory-governed serving.
//!
//! P-mode factor storage is the dominant memory constraint of the fully
//! batched H-matrix design (the paper's §5.4/§6.1), and Boukaram,
//! Turkiyyah & Keyes (2019) show that algebraic compression of
//! already-built hierarchical operators is itself a batchable many-core
//! workload that directly buys serving capacity. This module treats
//! compression as a first-class, operator-wide resource-management layer
//! rather than a per-block afterthought:
//!
//! * [`truncate`] — **budgeted global truncation**: one waterfilling
//!   problem over every admissible block's core spectrum ("spend rank
//!   where the spectrum says it matters"), targeting either a global
//!   relative-error budget or an explicit byte budget. Reuses the
//!   QR+Jacobi-SVD kernels of [`crate::aca::recompress`].
//! * [`storage`] — **mixed-precision factor storage**: a compacted
//!   per-block store ([`PackedFactors`]) holding U/V stripes at their
//!   achieved rank (the flat k-stripe layout keeps its zero stripes
//!   allocated; packing reclaims them) in f32 where the error model
//!   allows, widening to f64 inside the batched matvec/matmat kernels.
//! * [`governor`] — a **[`MemoryGovernor`]** for
//!   [`crate::serve::OperatorRegistry`]: a cross-tenant factor-byte
//!   budget enforced by recompressing the coldest operators toward
//!   tighter budgets and, failing that, evicting idle LRU tenants.
//!
//! ## Error model
//!
//! For a relative budget ε ([`CompressBudget::RelErr`]), the discarded
//! singular mass obeys `Σ_disc σ² ≤ ε² Σ_all σ²`, i.e. the low-rank part
//! of the operator changes by at most ε in relative Frobenius norm.
//! Mixed-precision storage demotes a block to f32 only when its σ₁ keeps
//! the f32 roundoff (≈ 1.2e-7 · σ₁) below a quarter of the truncation
//! allowance, so the **advertised bound is 1.5 ε** relative Frobenius
//! error of the low-rank part (the property tests pin it). Byte budgets
//! are planned at 8 bytes/element, so an f32/mixed store always lands at
//! or under the requested bytes when the plan is feasible; an infeasible
//! budget (the rank-1 floor alone exceeds it) is visible as
//! `bytes_after > budget` in the returned [`CompressStats`].
//!
//! Every pass is timed under the `compress.pass` phase of
//! [`crate::metrics::RECORDER`].

pub mod governor;
pub mod storage;
pub mod truncate;

pub use governor::{GovernorAction, GovernorConfig, GovernorSnapshot, MemoryGovernor, TenantUsage};
pub use storage::{PackedFactors, StorageMode};
pub use truncate::{waterfill, BlockSpectrum, WaterfillResult};

use crate::aca::batched::AcaFactors;
use crate::aca::recompress::{core_svds, truncate_to_ranks};
use crate::obs::profile::{self, model};
use crate::tree::block::WorkItem;

/// f32 unit roundoff, widened — what demoting a factor stripe costs.
pub(crate) const F32_EPS: f64 = f32::EPSILON as f64;

/// What the global truncation is allowed to spend.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressBudget {
    /// Global relative-error target ε: discard singular mass up to
    /// `ε² · Σ σ²` across the whole operator.
    RelErr(f64),
    /// Explicit factor-byte budget (planned at 8 bytes/element; the
    /// packed store may land lower when blocks demote to f32).
    Bytes(usize),
}

/// One compression pass's policy: the budget plus the storage precision.
#[derive(Clone, Copy, Debug)]
pub struct CompressConfig {
    pub budget: CompressBudget,
    pub storage: StorageMode,
}

impl CompressConfig {
    /// Relative-error budget with mixed-precision storage (the default
    /// serving configuration).
    pub fn rel_err(eps: f64) -> Self {
        CompressConfig { budget: CompressBudget::RelErr(eps), storage: StorageMode::Mixed }
    }

    /// Byte budget with mixed-precision storage.
    pub fn bytes(budget: usize) -> Self {
        CompressConfig { budget: CompressBudget::Bytes(budget), storage: StorageMode::Mixed }
    }
}

/// Statistics of one operator-wide compression pass.
#[derive(Clone, Debug, Default)]
pub struct CompressStats {
    pub blocks: usize,
    pub rank_before: usize,
    pub rank_after: usize,
    /// Factor bytes held before the pass (the operator's previous store).
    pub bytes_before: usize,
    /// Factor bytes held after the pass (the packed store).
    pub bytes_after: usize,
    /// Blocks stored in f32 / f64 after the pass.
    pub f32_blocks: usize,
    pub f64_blocks: usize,
    /// Global singular-value threshold the waterfilling applied (0 when
    /// nothing was discarded).
    pub threshold: f64,
    /// Predicted relative Frobenius error of the low-rank part from the
    /// discarded singular mass (truncation only; see the module docs for
    /// the mixed-precision term).
    pub predicted_rel_err: f64,
}

impl CompressStats {
    /// `bytes_after / bytes_before` — the retained fraction of factor
    /// storage (0.25 ⇒ 4× smaller). Smaller is better.
    pub fn retained_fraction(&self) -> f64 {
        self.bytes_after as f64 / self.bytes_before.max(1) as f64
    }
}

/// Run one budgeted pass over an operator's ACA batches: export every
/// block's core spectrum, solve the global waterfilling, truncate each
/// batch to its chosen ranks, and pack the result into compacted
/// (optionally mixed-precision) stores. `batch_blocks[i]` is the
/// admissible-block slice backing `batches[i]` (the
/// [`crate::hmatrix::HMatrix`] batch-plan slices).
///
/// `stats.bytes_before` counts the *flat* layout of `batches`; a caller
/// replacing an already-packed store should overwrite it with the bytes
/// it actually held.
pub fn compress_batches(
    batches: &mut [AcaFactors],
    batch_blocks: &[&[WorkItem]],
    cfg: &CompressConfig,
) -> (Vec<PackedFactors>, CompressStats) {
    assert_eq!(batches.len(), batch_blocks.len());
    crate::metrics::timed(crate::obs::names::COMPRESS_PASS, || {
        let bytes_before: usize = batches.iter().map(|f| f.storage_bytes()).sum();
        let rank_before: usize = batches.iter().map(|f| f.ranks.iter().sum::<usize>()).sum();
        let nblocks: usize = batch_blocks.iter().map(|b| b.len()).sum();

        // 1. per-block core SVDs (parallel inside), spectra for the solve
        let cores: Vec<_> =
            batches.iter().zip(batch_blocks).map(|(f, blocks)| core_svds(f, blocks)).collect();
        let mut spectra = Vec::new();
        let mut fixed_bytes = 0usize; // degenerate blocks pass through
        for (bi, (batch_cores, f)) in cores.iter().zip(batches.iter()).enumerate() {
            for (blk, core) in batch_cores.iter().enumerate() {
                match core {
                    Some(c) => spectra.push(BlockSpectrum {
                        batch: bi,
                        block: blk,
                        rank_elems: c.m + c.n,
                        s: c.s.clone(),
                    }),
                    None => {
                        let m = f.row_offsets[blk + 1] - f.row_offsets[blk];
                        let n = f.col_offsets[blk + 1] - f.col_offsets[blk];
                        fixed_bytes += f.ranks[blk] * (m + n) * std::mem::size_of::<f64>();
                    }
                }
            }
        }

        // 2. one global waterfilling across every block's spectrum
        let solve_budget = match cfg.budget {
            CompressBudget::RelErr(eps) => CompressBudget::RelErr(eps),
            CompressBudget::Bytes(b) => CompressBudget::Bytes(b.saturating_sub(fixed_bytes)),
        };
        let plan = waterfill(&spectra, &solve_budget);

        // 3. per-block rank targets + precision decisions
        let mut ranks: Vec<Vec<usize>> = batches.iter().map(|f| f.ranks.clone()).collect();
        for (spec, &r) in spectra.iter().zip(&plan.ranks) {
            ranks[spec.batch][spec.block] = r;
        }
        let eps_tgt = match cfg.budget {
            CompressBudget::RelErr(eps) => eps,
            CompressBudget::Bytes(_) => plan.predicted_rel_err,
        };
        let mut fp32: Vec<Vec<bool>> =
            batch_blocks.iter().map(|b| vec![false; b.len()]).collect();
        match cfg.storage {
            StorageMode::F64 => {}
            StorageMode::F32 => {
                for flags in &mut fp32 {
                    flags.iter_mut().for_each(|f| *f = true);
                }
            }
            StorageMode::Mixed => {
                // aggregate-safe demotion ("fall back to f64 where σ₁
                // demands it"): rounding a block's factors to f32
                // perturbs its product by ≲ c·εf32·σ₁ (c a small
                // constant from the two perturbed factors), and B
                // demoted blocks can stack √B-fold in Frobenius. A block
                // demotes only while εf32·σ₁·√B ≤ ε·‖L‖_F / 8, which
                // keeps the aggregate mixed-precision term under 0.5 ε
                // ‖L‖_F even at c ≈ 4 — so the 1.5 ε advertised bound
                // holds in aggregate, not just per block
                let fro = spectra
                    .iter()
                    .flat_map(|sp| sp.s.iter().map(|&x| x * x))
                    .sum::<f64>()
                    .sqrt();
                let stack = (spectra.len().max(1) as f64).sqrt();
                for spec in &spectra {
                    if spec.s[0] * F32_EPS * stack <= 0.125 * eps_tgt * fro {
                        fp32[spec.batch][spec.block] = true;
                    }
                }
            }
        }

        // 4. truncate every batch to its chosen ranks, then pack compact
        let mut packed = Vec::with_capacity(batches.len());
        for (bi, (f, blocks)) in batches.iter_mut().zip(batch_blocks).enumerate() {
            // charge modeled truncation work before `f.ranks` is
            // overwritten: read at the old rank, rebuilt at the target
            if profile::is_enabled() {
                let mut tally = profile::Tally::new();
                for (blk, w) in blocks.iter().enumerate() {
                    let (k_old, r_new) = (f.ranks[blk], ranks[bi][blk]);
                    let key = profile::WorkKey::new(
                        profile::Phase::CompressPass,
                        profile::LEVEL_AGG,
                        profile::rank_class(r_new),
                        0,
                    );
                    let work = profile::Work {
                        flops: model::recompress_flops(w.rows(), w.cols(), k_old, r_new),
                        bytes: model::recompress_bytes(w.rows(), w.cols(), k_old, r_new),
                        items: 1,
                        ..profile::Work::default()
                    };
                    tally.add(key, work);
                }
                let batch_key = profile::WorkKey::new(
                    profile::Phase::CompressPass,
                    profile::LEVEL_AGG,
                    profile::CLASS_AGG,
                    0,
                );
                tally.add(
                    batch_key,
                    profile::Work { events: 1, ..profile::Work::default() },
                );
                tally.flush();
            }
            truncate_to_ranks(f, blocks, &cores[bi], &ranks[bi]);
            packed.push(PackedFactors::pack(f, blocks, &fp32[bi]));
        }

        let bytes_after: usize = packed.iter().map(|p| p.storage_bytes()).sum();
        let rank_after: usize = packed.iter().map(|p| p.stored_ranks()).sum();
        let f32_blocks: usize = packed.iter().map(|p| p.f32_blocks()).sum();
        let stats = CompressStats {
            blocks: nblocks,
            rank_before,
            rank_after,
            bytes_before,
            bytes_after,
            f32_blocks,
            f64_blocks: nblocks - f32_blocks,
            threshold: plan.threshold,
            predicted_rel_err: plan.predicted_rel_err,
        };
        (packed, stats)
    })
}
