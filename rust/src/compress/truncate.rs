//! Budgeted global rank truncation: one waterfilling problem across the
//! whole operator.
//!
//! Per-block recompression ([`crate::aca::recompress`]) truncates each
//! block against its *own* σ₁ — a block with a flat spectrum keeps rank
//! it does not deserve while a block with a steep spectrum is starved.
//! Operator-wide budgeting instead pools every block's core singular
//! values and discards the globally smallest mass first (relative-error
//! budget) or keeps the best σ²-per-byte candidates first (byte budget):
//! rank is spent where the spectrum says it matters.
//!
//! Both solves preserve within-block monotonicity for free: a block's
//! singular values are descending, so the kept set per block is always a
//! prefix and a per-block *count* fully describes the decision. Every
//! block keeps at least rank 1 — dropping admissible blocks entirely
//! changes the operator's sparsity pattern, which stays the tree's
//! decision, not the compressor's.

use super::CompressBudget;

/// One block's core spectrum handed to the global solve.
#[derive(Clone, Debug)]
pub struct BlockSpectrum {
    /// Index of the owning ACA batch.
    pub batch: usize,
    /// Block index within the batch.
    pub block: usize,
    /// `rows + cols` — one rank level of this block stores this many
    /// factor elements.
    pub rank_elems: usize,
    /// Core singular values, descending (see
    /// [`crate::aca::recompress::CoreSvd`]).
    pub s: Vec<f64>,
}

/// Outcome of the global solve, aligned with the input spectra.
#[derive(Clone, Debug)]
pub struct WaterfillResult {
    /// Chosen rank per spectrum (same order as the input), each in
    /// `1..=s.len()`.
    pub ranks: Vec<usize>,
    /// Largest discarded singular value (0 when nothing was discarded).
    pub threshold: f64,
    /// `sqrt(Σ_disc σ² / Σ_all σ²)`: predicted relative Frobenius error
    /// of the low-rank part.
    pub predicted_rel_err: f64,
    /// Planned factor bytes for the kept ranks at 8 bytes/element.
    pub planned_bytes: usize,
}

/// A discardable singular value: `(spectrum index, level ≥ 1, σ², bytes)`.
struct Candidate {
    spec: usize,
    sv2: f64,
    bytes: usize,
}

/// Solve the operator-wide truncation problem. See the module docs for
/// the two budget semantics. With an empty spectrum list the result is
/// trivially empty.
pub fn waterfill(spectra: &[BlockSpectrum], budget: &CompressBudget) -> WaterfillResult {
    let elem = std::mem::size_of::<f64>();
    let total_fro2: f64 = spectra.iter().flat_map(|sp| sp.s.iter().map(|&x| x * x)).sum();
    let mut ranks: Vec<usize> = spectra.iter().map(|sp| sp.s.len()).collect();
    if total_fro2 <= 0.0 {
        let planned_bytes = planned_bytes(spectra, &ranks, elem);
        return WaterfillResult { ranks, threshold: 0.0, predicted_rel_err: 0.0, planned_bytes };
    }

    // every level ≥ 1 is a discard candidate; level 0 is mandatory
    let mut cands: Vec<Candidate> = Vec::new();
    for (si, sp) in spectra.iter().enumerate() {
        for &sv in sp.s.iter().skip(1) {
            cands.push(Candidate { spec: si, sv2: sv * sv, bytes: sp.rank_elems * elem });
        }
    }

    let mut discarded2 = 0.0f64;
    let mut threshold = 0.0f64;
    match *budget {
        CompressBudget::RelErr(eps) => {
            // discard the globally smallest singular mass first while the
            // cumulative discard stays within ε² · Σ σ²
            let allowance = (eps * eps) * total_fro2;
            cands.sort_by(|a, b| a.sv2.total_cmp(&b.sv2));
            for c in &cands {
                if discarded2 + c.sv2 > allowance {
                    break;
                }
                discarded2 += c.sv2;
                ranks[c.spec] -= 1;
                threshold = threshold.max(c.sv2.sqrt());
            }
        }
        CompressBudget::Bytes(budget_bytes) => {
            // mandatory rank-1 floor first, then keep the best σ² per byte
            let mut used: usize = spectra.iter().map(|sp| sp.rank_elems * elem).sum();
            cands.sort_by(|a, b| {
                let da = a.sv2 / a.bytes as f64;
                let db = b.sv2 / b.bytes as f64;
                db.total_cmp(&da)
            });
            // everything starts discarded; buy back in value order. A
            // candidate that does not fit is SKIPPED, not a stopping
            // point: a cheaper block's level further down may still fit
            // and use up the remaining budget. Within one block all
            // levels cost the same, so the kept set per block stays a
            // prefix and count-based ranks remain valid.
            for r in &mut ranks {
                *r = 1;
            }
            discarded2 = cands.iter().map(|c| c.sv2).sum();
            for c in &cands {
                if used + c.bytes > budget_bytes {
                    // stays discarded — the largest such σ is the threshold
                    threshold = threshold.max(c.sv2.sqrt());
                    continue;
                }
                used += c.bytes;
                ranks[c.spec] += 1;
                discarded2 -= c.sv2;
            }
        }
    }
    let predicted_rel_err = (discarded2 / total_fro2).sqrt();
    let planned_bytes = planned_bytes(spectra, &ranks, elem);
    WaterfillResult { ranks, threshold, predicted_rel_err, planned_bytes }
}

fn planned_bytes(spectra: &[BlockSpectrum], ranks: &[usize], elem: usize) -> usize {
    spectra.iter().zip(ranks).map(|(sp, &r)| r * sp.rank_elems * elem).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(batch: usize, block: usize, rank_elems: usize, s: &[f64]) -> BlockSpectrum {
        BlockSpectrum { batch, block, rank_elems, s: s.to_vec() }
    }

    #[test]
    fn rel_err_budget_discards_smallest_mass_globally() {
        // block A has a steep spectrum, block B a flat one: the budget
        // must starve A's tail before touching B's head.
        let spectra = vec![
            spec(0, 0, 100, &[10.0, 1e-6, 1e-7, 1e-8]),
            spec(0, 1, 100, &[5.0, 4.0, 3.0, 2.0]),
        ];
        let plan = waterfill(&spectra, &CompressBudget::RelErr(1e-5));
        assert_eq!(plan.ranks[0], 1, "steep block must drop its tail");
        assert_eq!(plan.ranks[1], 4, "flat block must keep everything");
        assert!(plan.predicted_rel_err <= 1e-5, "{}", plan.predicted_rel_err);
        assert!(plan.threshold >= 1e-7 && plan.threshold < 1e-5, "{}", plan.threshold);
    }

    #[test]
    fn zero_budget_keeps_everything() {
        let spectra = vec![spec(0, 0, 10, &[3.0, 2.0, 1.0])];
        let plan = waterfill(&spectra, &CompressBudget::RelErr(0.0));
        assert_eq!(plan.ranks, vec![3]);
        assert_eq!(plan.threshold, 0.0);
        assert_eq!(plan.predicted_rel_err, 0.0);
        assert_eq!(plan.planned_bytes, 3 * 10 * 8);
    }

    #[test]
    fn byte_budget_buys_best_value_per_byte() {
        // same σ, but block 1 is 10× cheaper per rank level: the budget
        // should prefer its levels
        let spectra = vec![
            spec(0, 0, 1000, &[10.0, 9.0, 8.0]),
            spec(0, 1, 100, &[10.0, 9.0, 8.0]),
        ];
        // floor: (1000 + 100) * 8 = 8800; leave room for block 1's two
        // extra levels (2 * 100 * 8 = 1600) but not block 0's
        let plan = waterfill(&spectra, &CompressBudget::Bytes(8800 + 1600));
        assert_eq!(plan.ranks[1], 3, "cheap block keeps full rank");
        assert_eq!(plan.ranks[0], 1, "expensive block truncated to the floor");
        assert!(plan.planned_bytes <= 8800 + 1600);
        assert!(plan.threshold >= 9.0, "dropped σ must set the threshold: {}", plan.threshold);
    }

    #[test]
    fn infeasible_byte_budget_keeps_rank_one_floor() {
        let spectra = vec![spec(0, 0, 100, &[2.0, 1.0]), spec(1, 3, 100, &[2.0, 1.0])];
        let plan = waterfill(&spectra, &CompressBudget::Bytes(10));
        assert_eq!(plan.ranks, vec![1, 1], "floor is never sold");
        assert!(plan.planned_bytes > 10, "infeasibility must be visible");
    }

    #[test]
    fn generous_byte_budget_keeps_everything() {
        let spectra = vec![spec(0, 0, 50, &[3.0, 2.0, 1.0])];
        let plan = waterfill(&spectra, &CompressBudget::Bytes(1 << 30));
        assert_eq!(plan.ranks, vec![3]);
        assert_eq!(plan.threshold, 0.0);
        assert_eq!(plan.predicted_rel_err, 0.0);
    }

    #[test]
    fn empty_spectra_are_trivial() {
        let plan = waterfill(&[], &CompressBudget::RelErr(1e-3));
        assert!(plan.ranks.is_empty());
        assert_eq!(plan.planned_bytes, 0);
    }
}
