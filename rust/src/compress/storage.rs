//! Compacted, optionally mixed-precision P-mode factor storage.
//!
//! The flat Fig-10 layout ([`AcaFactors`]) allocates k stripes for every
//! block; after truncation the retired stripes are zeroed but their
//! memory stays allocated. [`PackedFactors`] stores each block's U/V
//! stripes contiguously at the *achieved* rank, and per block in either
//! f64 or f32 — the precision decision is error-controlled upstream
//! ([`crate::compress::compress_batches`]): blocks whose σ₁ demands f64
//! keep it, the rest halve their bytes. The batched matvec/matmat kernel
//! widens f32 stripes to f64 element-by-element inside the inner loops,
//! so accumulation stays in f64 and the API (column-major n × nrhs in
//! and out) is unchanged.

use crate::aca::batched::AcaFactors;
use crate::dpp::executor::launch_with_grain;
use crate::dpp::scan::exclusive_scan;
use crate::obs::profile::{self, model};
use crate::tree::block::WorkItem;
use crate::util::atomic::AtomicF64Vec;

/// One block's y += U (Vᵀ x) over all RHS columns and rank levels, shared
/// by the f32 and f64 arenas: every element is widened to f64 on load
/// (`T: Into<f64>` — lossless for both precisions) so the accumulation
/// itself is identical regardless of storage. `y` is m × nrhs, `t` is the
/// per-level nrhs-wide dot-product scratch.
#[allow(clippy::too_many_arguments)]
fn block_apply<T: Copy + Into<f64>>(
    ua: &[T],
    va: &[T],
    p: &PackedBlock,
    w: &WorkItem,
    x: &[f64],
    n: usize,
    y: &mut [f64],
    t: &mut [f64],
) {
    let m = p.m;
    for l in 0..p.rank {
        let vl = &va[p.v_off + l * p.n..p.v_off + (l + 1) * p.n];
        for (c, tc) in t.iter_mut().enumerate() {
            let xs = &x[c * n + w.sigma.lo..c * n + w.sigma.hi];
            let mut acc = 0.0;
            for (&v, xv) in vl.iter().zip(xs) {
                let v: f64 = v.into();
                acc += v * xv;
            }
            *tc = acc;
        }
        let ul = &ua[p.u_off + l * m..p.u_off + (l + 1) * m];
        for (c, &tc) in t.iter().enumerate() {
            if tc == 0.0 {
                continue;
            }
            for (yi, &u) in y[c * m..(c + 1) * m].iter_mut().zip(ul) {
                let u: f64 = u.into();
                *yi += tc * u;
            }
        }
    }
}

/// Factor storage precision policy for a compression pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageMode {
    /// Keep every block in f64.
    F64,
    /// Per-block choice: f32 where the error model allows, f64 where σ₁
    /// demands it (the default).
    Mixed,
    /// Force every block to f32 (no error control — benchmarks only).
    F32,
}

/// Directory entry: where one block's stripes live.
#[derive(Clone, Copy, Debug)]
struct PackedBlock {
    m: usize,
    n: usize,
    rank: usize,
    /// Offset (elements) of stripe 0 of U in the block's arena; stripe l
    /// starts at `u_off + l * m`.
    u_off: usize,
    /// Offset (elements) of stripe 0 of V; stripe l at `v_off + l * n`.
    v_off: usize,
    /// Which arena: true → the f32 arenas.
    fp32: bool,
}

/// Compacted per-block factor store for one ACA batch (see module docs).
pub struct PackedFactors {
    dir: Vec<PackedBlock>,
    u32a: Vec<f32>,
    v32a: Vec<f32>,
    u64a: Vec<f64>,
    v64a: Vec<f64>,
    /// Rank cap of the source factors (kept so [`PackedFactors::unpack`]
    /// can rebuild the flat layout for a further compression pass).
    k: usize,
}

impl PackedFactors {
    /// Pack `factors` (flat layout) into the compacted store; `fp32[b]`
    /// selects the f32 arenas for block `b`.
    pub fn pack(factors: &AcaFactors, blocks: &[WorkItem], fp32: &[bool]) -> Self {
        let nb = blocks.len();
        assert_eq!(fp32.len(), nb);
        assert_eq!(factors.ranks.len(), nb);
        let total_m = *factors.row_offsets.last().unwrap();
        let total_n = *factors.col_offsets.last().unwrap();
        let mut dir = Vec::with_capacity(nb);
        let (mut u32a, mut v32a) = (Vec::new(), Vec::new());
        let (mut u64a, mut v64a) = (Vec::new(), Vec::new());
        for b in 0..nb {
            let (rlo, rhi) = (factors.row_offsets[b], factors.row_offsets[b + 1]);
            let (clo, chi) = (factors.col_offsets[b], factors.col_offsets[b + 1]);
            let m = rhi - rlo;
            let n = chi - clo;
            let rank = factors.ranks[b];
            let (u_off, v_off) =
                if fp32[b] { (u32a.len(), v32a.len()) } else { (u64a.len(), v64a.len()) };
            for l in 0..rank {
                let us = &factors.u_all[l * total_m + rlo..l * total_m + rhi];
                let vs = &factors.v_all[l * total_n + clo..l * total_n + chi];
                if fp32[b] {
                    u32a.extend(us.iter().map(|&x| x as f32));
                    v32a.extend(vs.iter().map(|&x| x as f32));
                } else {
                    u64a.extend_from_slice(us);
                    v64a.extend_from_slice(vs);
                }
            }
            dir.push(PackedBlock { m, n, rank, u_off, v_off, fp32: fp32[b] });
        }
        PackedFactors { dir, u32a, v32a, u64a, v64a, k: factors.k }
    }

    /// Widen back into the flat f64 layout — what a further compression
    /// pass (governor tightening an already-packed operator) runs on.
    pub fn unpack(&self, blocks: &[WorkItem]) -> AcaFactors {
        let nb = blocks.len();
        assert_eq!(self.dir.len(), nb);
        let rows: Vec<usize> = self.dir.iter().map(|p| p.m).collect();
        let cols: Vec<usize> = self.dir.iter().map(|p| p.n).collect();
        let row_offsets = exclusive_scan(&rows);
        let col_offsets = exclusive_scan(&cols);
        let total_m = row_offsets[nb];
        let total_n = col_offsets[nb];
        let mut u_all = vec![0.0f64; self.k * total_m];
        let mut v_all = vec![0.0f64; self.k * total_n];
        let mut ranks = vec![0usize; nb];
        for (b, p) in self.dir.iter().enumerate() {
            ranks[b] = p.rank;
            for l in 0..p.rank {
                let u_dst =
                    &mut u_all[l * total_m + row_offsets[b]..l * total_m + row_offsets[b] + p.m];
                let v_dst =
                    &mut v_all[l * total_n + col_offsets[b]..l * total_n + col_offsets[b] + p.n];
                if p.fp32 {
                    let us = &self.u32a[p.u_off + l * p.m..p.u_off + (l + 1) * p.m];
                    let vs = &self.v32a[p.v_off + l * p.n..p.v_off + (l + 1) * p.n];
                    for (d, s) in u_dst.iter_mut().zip(us) {
                        *d = f64::from(*s);
                    }
                    for (d, s) in v_dst.iter_mut().zip(vs) {
                        *d = f64::from(*s);
                    }
                } else {
                    u_dst.copy_from_slice(&self.u64a[p.u_off + l * p.m..p.u_off + (l + 1) * p.m]);
                    v_dst.copy_from_slice(&self.v64a[p.v_off + l * p.n..p.v_off + (l + 1) * p.n]);
                }
            }
        }
        AcaFactors { u_all, v_all, row_offsets, col_offsets, ranks, k: self.k }
    }

    /// Single-RHS apply (see [`PackedFactors::apply_mat`]).
    pub fn apply(&self, blocks: &[WorkItem], x: &[f64], z: &AtomicF64Vec) {
        self.apply_mat(blocks, x, 1, z);
    }

    /// Multi-RHS apply: z|τ_b += U_b (V_bᵀ X|σ_b) for every RHS column,
    /// mirroring [`AcaFactors::apply_mat`] (same column-major layout and
    /// per-block parallel launch); f32 stripes are widened to f64 inside
    /// the inner loops so every accumulation runs in f64.
    pub fn apply_mat(&self, blocks: &[WorkItem], x: &[f64], nrhs: usize, z: &AtomicF64Vec) {
        let nb = blocks.len();
        assert_eq!(self.dir.len(), nb);
        if nb == 0 || nrhs == 0 {
            return;
        }
        debug_assert_eq!(x.len() % nrhs, 0);
        let n = x.len() / nrhs;
        if profile::is_enabled() {
            let mut tally = profile::Tally::new();
            for (p, w) in self.dir.iter().zip(blocks) {
                if p.rank == 0 {
                    continue;
                }
                let elem_bytes = if p.fp32 { 4 } else { 8 };
                let key = profile::WorkKey::new(
                    profile::Phase::LowRankApply,
                    profile::level_of(n, w.rows()),
                    profile::rank_class(p.rank),
                    profile::width_of(nrhs),
                );
                let work = profile::Work {
                    flops: model::lowrank_apply_flops(p.m, p.n, p.rank, nrhs),
                    bytes: model::lowrank_apply_bytes(p.m, p.n, p.rank, nrhs, elem_bytes),
                    items: 1,
                    ..profile::Work::default()
                };
                tally.add(key, work);
            }
            tally.flush();
        }
        launch_with_grain(nb, 1, |b| {
            let p = &self.dir[b];
            let w = &blocks[b];
            let m = p.m;
            if p.rank == 0 {
                return;
            }
            // y_c = Σ_r (v_r · x_c) u_r, accumulated locally then scattered
            // once per row per column (atomic: blocks may share τ rows).
            let mut y = vec![0.0f64; m * nrhs];
            let mut t = vec![0.0f64; nrhs];
            if p.fp32 {
                block_apply(&self.u32a, &self.v32a, p, w, x, n, &mut y, &mut t);
            } else {
                block_apply(&self.u64a, &self.v64a, p, w, x, n, &mut y, &mut t);
            }
            for (c, yc) in y.chunks_exact(m).enumerate() {
                for (i, yi) in yc.iter().enumerate() {
                    z.add(c * n + w.tau.lo + i, *yi);
                }
            }
        });
    }

    /// Bytes of factor storage actually held (4 bytes per f32 element,
    /// 8 per f64 — the honest P-mode footprint).
    pub fn storage_bytes(&self) -> usize {
        (self.u32a.len() + self.v32a.len()) * std::mem::size_of::<f32>()
            + (self.u64a.len() + self.v64a.len()) * std::mem::size_of::<f64>()
    }

    /// Stored factor elements Σ_b r_b (m_b + n_b) — what the element-based
    /// [`crate::hmatrix::HMatrix::compression_ratio`] counts.
    pub fn stored_elems(&self) -> usize {
        self.u32a.len() + self.v32a.len() + self.u64a.len() + self.v64a.len()
    }

    /// Sum of stored ranks across blocks.
    pub fn stored_ranks(&self) -> usize {
        self.dir.iter().map(|p| p.rank).sum()
    }

    /// Achieved rank per block, in block order (what the conservation
    /// tests and `HMatrix::flops_per_col` recompute work models from).
    pub fn block_ranks(&self) -> Vec<usize> {
        self.dir.iter().map(|p| p.rank).collect()
    }

    /// Whether block `b` is stored in the f32 arenas.
    pub fn is_fp32(&self, b: usize) -> bool {
        self.dir[b].fp32
    }

    /// Blocks stored in f32.
    pub fn f32_blocks(&self) -> usize {
        self.dir.iter().filter(|p| p.fp32).count()
    }

    pub fn blocks(&self) -> usize {
        self.dir.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aca::batched::{batched_aca_factors, AcaBatch};
    use crate::geometry::kernel::Kernel;
    use crate::geometry::points::PointSet;
    use crate::morton::morton_sort;
    use crate::tree::block::build_block_tree;

    fn factors_for(n: usize, k: usize) -> (PointSet, Vec<WorkItem>, AcaFactors) {
        let mut pts = PointSet::halton(n, 2);
        morton_sort(&mut pts);
        let t = build_block_tree(&pts, 1.5, 32);
        let blocks = t.admissible;
        let f = batched_aca_factors(&AcaBatch {
            points: &pts,
            kernel: Kernel::gaussian(),
            blocks: &blocks,
            k,
        });
        (pts, blocks, f)
    }

    #[test]
    fn f64_pack_applies_identically_and_shrinks_storage() {
        let (pts, blocks, f) = factors_for(1024, 16);
        let n = pts.len();
        let packed = PackedFactors::pack(&f, &blocks, &vec![false; blocks.len()]);
        assert_eq!(packed.blocks(), blocks.len());
        assert_eq!(packed.f32_blocks(), 0);
        // packing drops the zero stripes the flat layout keeps allocated
        assert!(packed.storage_bytes() <= f.storage_bytes());
        for nrhs in [1usize, 3] {
            let x = crate::util::prng::Xoshiro256::seed(11 + nrhs as u64).vector(n * nrhs);
            let zf = AtomicF64Vec::zeros(n * nrhs);
            f.apply_mat(&blocks, &x, nrhs, &zf);
            let zp = AtomicF64Vec::zeros(n * nrhs);
            packed.apply_mat(&blocks, &x, nrhs, &zp);
            let err = crate::util::rel_err(&zp.into_vec(), &zf.into_vec());
            assert!(err < 1e-14, "f64 pack must be lossless: nrhs={nrhs} {err}");
        }
    }

    #[test]
    fn f32_pack_halves_bytes_with_bounded_error() {
        let (pts, blocks, f) = factors_for(1024, 12);
        let n = pts.len();
        let p64 = PackedFactors::pack(&f, &blocks, &vec![false; blocks.len()]);
        let p32 = PackedFactors::pack(&f, &blocks, &vec![true; blocks.len()]);
        assert_eq!(p32.f32_blocks(), blocks.len());
        assert_eq!(p32.storage_bytes() * 2, p64.storage_bytes());
        let x = crate::util::prng::Xoshiro256::seed(5).vector(n);
        let zf = AtomicF64Vec::zeros(n);
        f.apply(&blocks, &x, &zf);
        let zp = AtomicF64Vec::zeros(n);
        p32.apply(&blocks, &x, &zp);
        let err = crate::util::rel_err(&zp.into_vec(), &zf.into_vec());
        assert!(err < 1e-5, "f32 storage error too large: {err}");
        assert!(err > 0.0, "f32 storage should round somewhere");
    }

    #[test]
    fn unpack_round_trips_the_apply() {
        let (pts, blocks, f) = factors_for(512, 10);
        let n = pts.len();
        let fp32: Vec<bool> = (0..blocks.len()).map(|b| b % 2 == 0).collect();
        let packed = PackedFactors::pack(&f, &blocks, &fp32);
        assert!(packed.f32_blocks() > 0);
        let unpacked = packed.unpack(&blocks);
        assert_eq!(unpacked.ranks, f.ranks);
        assert_eq!(unpacked.k, f.k);
        let x = crate::util::prng::Xoshiro256::seed(6).vector(n);
        let za = AtomicF64Vec::zeros(n);
        packed.apply(&blocks, &x, &za);
        let zb = AtomicF64Vec::zeros(n);
        unpacked.apply(&blocks, &x, &zb);
        // the unpacked flat layout holds the same (possibly rounded)
        // values, so applies agree to f64 roundoff
        let err = crate::util::rel_err(&zb.into_vec(), &za.into_vec());
        assert!(err < 1e-14, "unpack changed the operator: {err}");
    }

    #[test]
    fn empty_batch_is_noop() {
        let pts = PointSet::halton(16, 2);
        let f = batched_aca_factors(&AcaBatch {
            points: &pts,
            kernel: Kernel::gaussian(),
            blocks: &[],
            k: 4,
        });
        let packed = PackedFactors::pack(&f, &[], &[]);
        assert_eq!(packed.storage_bytes(), 0);
        let z = AtomicF64Vec::zeros(16);
        let x = vec![0.0; 16];
        packed.apply(&[], &x, &z);
    }
}
