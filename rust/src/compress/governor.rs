//! Cross-tenant memory governor for the serving registry.
//!
//! [`crate::serve::OperatorRegistry`] will happily build tenants until
//! the process OOMs; the governor gives it a hard cross-tenant budget on
//! P-mode factor bytes. Policy, on an over-budget admission:
//!
//! 1. **Recompress** the coldest compressible operators toward tighter
//!    byte budgets (floored at a configurable fraction of their current
//!    size so a hot spectrum is not squeezed to uselessness);
//! 2. failing that, **evict** idle LRU tenants (their executors drain
//!    in-flight batches gracefully; an evicted tenant rebuilds on its
//!    next `get_or_build`);
//! 3. failing even that, **reject** the incoming tenant — the ceiling is
//!    never exceeded.
//!
//! The policy is a pure function ([`MemoryGovernor::next_action`]) over a
//! usage snapshot, so it is unit-testable without building operators;
//! the registry executes one action at a time and re-snapshots. Every
//! decision is counted in the governor's stats and mirrored into
//! [`crate::metrics::RECORDER`] (`governor.recompress`, `governor.evict`,
//! `governor.reject`).

use std::sync::atomic::{AtomicU64, Ordering};

use super::storage::StorageMode;
use crate::obs::names;

/// Governor policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct GovernorConfig {
    /// Hard ceiling on summed P-mode factor bytes across tenants.
    pub budget_bytes: usize,
    /// A recompression victim is never asked to shrink below this
    /// fraction of its current bytes in one step (0 < floor < 1).
    pub recompress_floor: f64,
    /// Storage precision used for governor-initiated recompressions.
    pub storage: StorageMode,
    /// Soft-limit fraction of `budget_bytes` (0 < w ≤ 1). In the
    /// *pressure band* — total above `w * budget` but still under the
    /// hard budget — the governor tightens compression on live tenants
    /// (recompress only, never evict/reject), so brown-out pressure is
    /// relieved before the ceiling is ever hit. `1.0` (the default)
    /// disables the band: the classic hard-budget-only ladder.
    pub pressure_watermark: f64,
}

impl GovernorConfig {
    pub fn new(budget_bytes: usize) -> Self {
        GovernorConfig {
            budget_bytes,
            recompress_floor: 0.25,
            storage: StorageMode::Mixed,
            pressure_watermark: 1.0,
        }
    }

    /// Set the soft-limit fraction (clamped to (0, 1]).
    pub fn with_pressure_watermark(mut self, w: f64) -> Self {
        self.pressure_watermark = if w.is_finite() { w.clamp(f64::MIN_POSITIVE, 1.0) } else { 1.0 };
        self
    }

    /// The soft limit in bytes: recompression pressure starts here.
    pub fn soft_limit_bytes(&self) -> usize {
        ((self.budget_bytes as f64 * self.pressure_watermark) as usize).min(self.budget_bytes)
    }
}

/// One tenant's standing in the governor's eyes (a registry snapshot).
#[derive(Clone, Debug)]
pub struct TenantUsage {
    pub id: String,
    /// Current P-mode factor bytes (0 for NP-mode tenants).
    pub bytes: usize,
    /// Last access time, milliseconds since the registry epoch.
    pub last_access_ms: u64,
    /// Whether a recompression could still shrink this tenant (P mode
    /// and not yet driven to its floor).
    pub compressible: bool,
}

/// What the registry should do next to get back under budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GovernorAction {
    /// Ask `id`'s executor to recompress toward `target_bytes`.
    Recompress { id: String, target_bytes: usize },
    /// Remove `id` (graceful drain; rebuilds on next `get_or_build`).
    Evict { id: String },
    /// The incoming tenant cannot fit even alone: remove it and fail the
    /// registration.
    Reject { id: String },
}

/// Decision counters (`BatcherStats`-style; all thread-safe).
#[derive(Default)]
pub struct GovernorStats {
    recompressions: AtomicU64,
    evictions: AtomicU64,
    rejections: AtomicU64,
    /// Last observed cross-tenant byte total.
    bytes_in_use: AtomicU64,
}

/// Point-in-time view of the governor's counters.
#[derive(Clone, Debug)]
pub struct GovernorSnapshot {
    pub budget_bytes: usize,
    pub bytes_in_use: u64,
    pub recompressions: u64,
    pub evictions: u64,
    pub rejections: u64,
}

/// The cross-tenant byte-budget enforcer handed to
/// [`crate::serve::OperatorRegistry::with_governor`].
pub struct MemoryGovernor {
    pub cfg: GovernorConfig,
    stats: GovernorStats,
}

impl MemoryGovernor {
    pub fn new(cfg: GovernorConfig) -> Self {
        MemoryGovernor { cfg, stats: GovernorStats::default() }
    }

    /// Convenience: a byte budget with default policy knobs.
    pub fn with_budget(budget_bytes: usize) -> Self {
        MemoryGovernor::new(GovernorConfig::new(budget_bytes))
    }

    pub fn snapshot(&self) -> GovernorSnapshot {
        GovernorSnapshot {
            budget_bytes: self.cfg.budget_bytes,
            bytes_in_use: self.stats.bytes_in_use.load(Ordering::Relaxed),
            recompressions: self.stats.recompressions.load(Ordering::Relaxed),
            evictions: self.stats.evictions.load(Ordering::Relaxed),
            rejections: self.stats.rejections.load(Ordering::Relaxed),
        }
    }

    /// Pure policy step: given a usage snapshot and the id that was just
    /// admitted, return the next action, or `None` when under budget (or
    /// when nothing further can help — only possible if every tenant
    /// holds zero factor bytes, in which case the total is 0 ≤ budget
    /// anyway).
    pub fn next_action(
        &self,
        tenants: &[TenantUsage],
        incoming: &str,
    ) -> Option<GovernorAction> {
        let total: usize = tenants.iter().map(|t| t.bytes).sum();
        self.record_bytes(total);
        let soft = self.cfg.soft_limit_bytes();
        if total <= soft {
            return None;
        }
        // excess is measured against the SOFT limit: in the pressure band
        // recompressions aim below the watermark (headroom restored, not
        // just the ceiling grazed); with watermark 1.0 this is the
        // classic excess-over-budget
        let excess = total - soft;

        // 1. recompress the coldest compressible tenant (the incoming
        // one only once every other candidate is exhausted). With any
        // valid floor (< 1) the target is always a real shrink, so one
        // victim per step is the whole policy; the guard only protects
        // against a degenerate floor >= 1 config.
        let victim = tenants
            .iter()
            .filter(|t| t.compressible && t.bytes > 0)
            .min_by_key(|t| (t.id == incoming, t.last_access_ms));
        if let Some(v) = victim {
            let floor = (v.bytes as f64 * self.cfg.recompress_floor) as usize;
            let target = v.bytes.saturating_sub(excess).max(floor);
            if target < v.bytes {
                return Some(GovernorAction::Recompress {
                    id: v.id.clone(),
                    target_bytes: target,
                });
            }
        }

        // still under the HARD budget (pressure band only): compression
        // was the only permissible lever — never evict or reject a
        // tenant that fits under the ceiling
        if total <= self.cfg.budget_bytes {
            return None;
        }

        // 2. evict the coldest idle tenant that actually frees bytes
        let victim = tenants
            .iter()
            .filter(|t| t.id != incoming && t.bytes > 0)
            .min_by_key(|t| t.last_access_ms);
        if let Some(v) = victim {
            return Some(GovernorAction::Evict { id: v.id.clone() });
        }

        // 3. only the incoming tenant is left holding bytes: reject it
        if tenants.iter().any(|t| t.id == incoming && t.bytes > 0) {
            return Some(GovernorAction::Reject { id: incoming.to_string() });
        }
        None
    }

    pub(crate) fn record_recompress(&self) {
        self.stats.recompressions.fetch_add(1, Ordering::Relaxed);
        crate::metrics::RECORDER.incr(names::GOVERNOR_RECOMPRESS);
        crate::obs::counter_incr(names::GOVERNOR_RECOMPRESS);
    }

    pub(crate) fn record_evict(&self) {
        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        crate::metrics::RECORDER.incr(names::GOVERNOR_EVICT);
        crate::obs::counter_incr(names::GOVERNOR_EVICT);
    }

    pub(crate) fn record_reject(&self) {
        self.stats.rejections.fetch_add(1, Ordering::Relaxed);
        crate::metrics::RECORDER.incr(names::GOVERNOR_REJECT);
        crate::obs::counter_incr(names::GOVERNOR_REJECT);
    }

    pub(crate) fn record_bytes(&self, total: usize) {
        self.stats.bytes_in_use.store(total as u64, Ordering::Relaxed);
        crate::obs::gauge_set(names::GOVERNOR_BYTES_IN_USE, total as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: &str, bytes: usize, last_access_ms: u64, compressible: bool) -> TenantUsage {
        TenantUsage { id: id.to_string(), bytes, last_access_ms, compressible }
    }

    #[test]
    fn under_budget_is_a_noop() {
        let gov = MemoryGovernor::with_budget(1000);
        let tenants = vec![t("a", 400, 0, true), t("b", 500, 1, true)];
        assert_eq!(gov.next_action(&tenants, "b"), None);
        assert_eq!(gov.snapshot().bytes_in_use, 900);
    }

    #[test]
    fn recompresses_coldest_first_and_respects_floor() {
        let gov = MemoryGovernor::with_budget(1000);
        // total 1400, excess 400; "cold" (oldest access) is compressible
        let tenants =
            vec![t("cold", 600, 10, true), t("warm", 500, 500, true), t("new", 300, 900, true)];
        match gov.next_action(&tenants, "new") {
            Some(GovernorAction::Recompress { id, target_bytes }) => {
                assert_eq!(id, "cold");
                assert_eq!(target_bytes, 200, "600 - 400 excess, above the 150 floor");
            }
            other => panic!("expected recompress, got {other:?}"),
        }
        // huge excess: the target clamps at the floor instead of zero
        let tenants2 = vec![t("cold", 600, 10, true), t("new", 5000, 900, true)];
        match gov.next_action(&tenants2, "new") {
            Some(GovernorAction::Recompress { id, target_bytes }) => {
                assert_eq!(id, "cold");
                assert_eq!(target_bytes, 150, "floor = 0.25 * 600");
            }
            other => panic!("expected floored recompress, got {other:?}"),
        }
    }

    #[test]
    fn incoming_tenant_is_compressed_last() {
        let gov = MemoryGovernor::with_budget(100);
        // only the incoming tenant is compressible → it is the victim
        let tenants = vec![t("old", 80, 0, false), t("new", 80, 10, true)];
        match gov.next_action(&tenants, "new") {
            Some(GovernorAction::Recompress { id, .. }) => assert_eq!(id, "new"),
            other => panic!("expected recompress of the incoming tenant, got {other:?}"),
        }
    }

    #[test]
    fn evicts_lru_when_nothing_is_compressible() {
        let gov = MemoryGovernor::with_budget(100);
        let tenants =
            vec![t("oldest", 60, 5, false), t("recent", 60, 50, false), t("new", 60, 99, false)];
        assert_eq!(
            gov.next_action(&tenants, "new"),
            Some(GovernorAction::Evict { id: "oldest".to_string() })
        );
    }

    #[test]
    fn rejects_incoming_when_alone_and_oversized() {
        let gov = MemoryGovernor::with_budget(100);
        let tenants = vec![t("new", 500, 0, false)];
        assert_eq!(
            gov.next_action(&tenants, "new"),
            Some(GovernorAction::Reject { id: "new".to_string() })
        );
        gov.record_reject();
        assert_eq!(gov.snapshot().rejections, 1);
    }

    #[test]
    fn pressure_band_recompresses_but_never_evicts() {
        let cfg = GovernorConfig::new(1000).with_pressure_watermark(0.8);
        let gov = MemoryGovernor::new(cfg);
        assert_eq!(cfg.soft_limit_bytes(), 800);
        // total 900: above the 800 soft limit, under the 1000 hard budget
        let tenants = vec![t("cold", 500, 0, true), t("hot", 400, 50, true)];
        match gov.next_action(&tenants, "hot") {
            Some(GovernorAction::Recompress { id, target_bytes }) => {
                assert_eq!(id, "cold");
                // excess over the SOFT limit: 900 - 800 = 100 → 400
                assert_eq!(target_bytes, 400);
            }
            other => panic!("expected pressure-band recompress, got {other:?}"),
        }
        // same band with NOTHING compressible: no eviction while under
        // the hard budget — the band is advisory pressure only
        let stuck = vec![t("cold", 500, 0, false), t("hot", 400, 50, false)];
        assert_eq!(gov.next_action(&stuck, "hot"), None);
        // below the soft limit: silent
        let calm = vec![t("cold", 400, 0, true), t("hot", 300, 50, true)];
        assert_eq!(gov.next_action(&calm, "hot"), None);
        // watermark 1.0 keeps the legacy semantics (soft == hard)
        assert_eq!(GovernorConfig::new(1000).soft_limit_bytes(), 1000);
    }

    #[test]
    fn np_mode_tenants_never_block_admission() {
        let gov = MemoryGovernor::with_budget(100);
        // zero-byte tenants cannot be over budget in the first place
        let tenants = vec![t("np1", 0, 0, false), t("np2", 0, 1, false)];
        assert_eq!(gov.next_action(&tenants, "np2"), None);
    }
}
