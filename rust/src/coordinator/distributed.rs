//! Multi-device work distribution — the paper's §7 future work:
//! "our new algorithms shall be extended to the use in a
//! distributed-memory, thus e.g. multi-GPU, context. This, however,
//! involves to build an appropriate load balancing for the work
//! distribution of ACA computations and dense matrix-vector products".
//!
//! This module implements that coordinator: a cost model for both work
//! queues, an LPT (longest-processing-time) partitioner across D virtual
//! devices, and a sharded mat-vec executor. Devices are *simulated* on
//! this testbed (each shard runs through the same engine; per-device cost
//! is tracked so imbalance and projected multi-device speedup are
//! measurable), but the partitioning/merging logic is exactly what a
//! multi-GPU deployment needs: per-device block shards plus an owner-side
//! accumulation of the shared output vector.

use crate::batch::plan::{plan_batches, BatchBudget};
use crate::config::HmxConfig;
use crate::coordinator::BatchEngine;
use crate::geometry::kernel::Kernel;
use crate::geometry::points::PointSet;
use crate::tree::block::WorkItem;
use crate::util::atomic::AtomicF64Vec;

/// Cost model for one block (relative units). Dense blocks cost the full
/// m·n assembly+dot; ACA blocks cost k·(m+n) column/row sweeps times the
/// per-rank overhead.
pub fn block_cost(w: &WorkItem, k: usize, dense: bool) -> f64 {
    if dense {
        (w.rows() * w.cols()) as f64
    } else {
        // k rank levels, each touching a residual column and row plus the
        // rank-update axpys (average k/2 per level)
        (k * (w.rows() + w.cols())) as f64 * (1.0 + k as f64 / 2.0)
    }
}

/// A shard: the block indices owned by one device, with its modeled cost.
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub dense_blocks: Vec<usize>,
    pub aca_blocks: Vec<usize>,
    pub modeled_cost: f64,
}

/// LPT partition of both work queues across `devices` shards.
pub fn partition_lpt(
    dense: &[WorkItem],
    admissible: &[WorkItem],
    k: usize,
    devices: usize,
) -> Vec<Shard> {
    assert!(devices >= 1);
    let mut shards = vec![Shard::default(); devices];
    // all (cost, kind, index) items, heaviest first (LPT)
    let mut items: Vec<(f64, bool, usize)> = dense
        .iter()
        .enumerate()
        .map(|(i, w)| (block_cost(w, k, true), true, i))
        .chain(
            admissible
                .iter()
                .enumerate()
                .map(|(i, w)| (block_cost(w, k, false), false, i)),
        )
        .collect();
    items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    for (cost, is_dense, idx) in items {
        // assign to the currently lightest shard
        let dst = shards
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.modeled_cost.partial_cmp(&b.1.modeled_cost).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        shards[dst].modeled_cost += cost;
        if is_dense {
            shards[dst].dense_blocks.push(idx);
        } else {
            shards[dst].aca_blocks.push(idx);
        }
    }
    shards
}

/// Load-balance quality: max shard cost / mean shard cost (1.0 = perfect).
pub fn imbalance(shards: &[Shard]) -> f64 {
    let max = shards.iter().map(|s| s.modeled_cost).fold(0.0, f64::max);
    let mean =
        shards.iter().map(|s| s.modeled_cost).sum::<f64>() / shards.len().max(1) as f64;
    if mean <= 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Result of a sharded apply: output (column-major n × nrhs) plus
/// per-device measured seconds.
pub struct ShardedMatvec {
    pub y: Vec<f64>,
    pub device_seconds: Vec<f64>,
    pub modeled_imbalance: f64,
}

/// Execute the H-mat-vec shard by shard (simulated devices), measuring
/// per-device time. The output vector is accumulated across shards the
/// way a multi-GPU owner-side reduction would. Single-RHS convenience
/// wrapper over [`sharded_matmat`].
#[allow(clippy::too_many_arguments)]
pub fn sharded_matvec(
    points: &PointSet,
    kernel: Kernel,
    cfg: &HmxConfig,
    dense: &[WorkItem],
    admissible: &[WorkItem],
    shards: &[Shard],
    engine: &dyn BatchEngine,
    x_morton: &[f64],
) -> ShardedMatvec {
    sharded_matmat(points, kernel, cfg, dense, admissible, shards, engine, x_morton, 1)
}

/// Multi-RHS sharded apply: `x_morton` is column-major n × nrhs (Morton
/// order). Every shard runs each of its batches over the WHOLE RHS block
/// through [`BatchEngine::dense_matmat`] / [`BatchEngine::aca_matmat`], so
/// per-device assembly and factor traffic are amortized across the
/// columns exactly as in the single-device [`crate::hmatrix::HMatrix::matmat`]
/// path — the RHS blocking Harbrecht & Zaspel (2018) rely on for
/// multi-GPU block solves.
#[allow(clippy::too_many_arguments)]
pub fn sharded_matmat(
    points: &PointSet,
    kernel: Kernel,
    cfg: &HmxConfig,
    dense: &[WorkItem],
    admissible: &[WorkItem],
    shards: &[Shard],
    engine: &dyn BatchEngine,
    x_morton: &[f64],
    nrhs: usize,
) -> ShardedMatvec {
    let n = points.len();
    assert!(nrhs >= 1, "nrhs must be at least 1");
    assert_eq!(x_morton.len(), n * nrhs, "x must be column-major n x nrhs");
    let z = AtomicF64Vec::zeros(n * nrhs);
    let mut device_seconds = Vec::with_capacity(shards.len());
    for shard in shards {
        let t0 = std::time::Instant::now();
        // gather this shard's blocks (keeping plan order) and run the
        // same batched pipeline the single-device path uses
        let dense_blocks: Vec<WorkItem> =
            shard.dense_blocks.iter().map(|&i| dense[i]).collect();
        let aca_blocks: Vec<WorkItem> =
            shard.aca_blocks.iter().map(|&i| admissible[i]).collect();
        let dense_shapes: Vec<_> = dense_blocks
            .iter()
            .map(|w| crate::batch::plan::BlockShape { rows: w.rows(), cols: w.cols() })
            .collect();
        let aca_shapes: Vec<_> = aca_blocks
            .iter()
            .map(|w| crate::batch::plan::BlockShape { rows: w.rows(), cols: w.cols() })
            .collect();
        let dplan = plan_batches(&dense_shapes, BatchBudget::DensePaddedElems { bs: cfg.bs_dense });
        let aplan = plan_batches(&aca_shapes, BatchBudget::AcaTotalRows { bs: cfg.bs_aca });
        for &(s, e) in &dplan.batches {
            engine.dense_matmat(points, kernel, &dense_blocks[s..e], x_morton, nrhs, &z);
        }
        for &(s, e) in &aplan.batches {
            engine.aca_matmat(points, kernel, cfg.k, &aca_blocks[s..e], x_morton, nrhs, &z);
        }
        device_seconds.push(t0.elapsed().as_secs_f64());
    }
    ShardedMatvec { y: z.into_vec(), device_seconds, modeled_imbalance: imbalance(shards) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeEngine;
    use crate::morton::morton_sort;
    use crate::prelude::*;
    use crate::tree::block::build_block_tree;

    fn setup(n: usize) -> (PointSet, Vec<WorkItem>, Vec<WorkItem>) {
        let mut pts = PointSet::halton(n, 2);
        morton_sort(&mut pts);
        let t = build_block_tree(&pts, 1.5, 64);
        (pts, t.dense, t.admissible)
    }

    #[test]
    fn partition_covers_all_blocks_exactly_once() {
        let (_, dense, adm) = setup(2048);
        for devices in [1usize, 2, 4, 7] {
            let shards = partition_lpt(&dense, &adm, 16, devices);
            assert_eq!(shards.len(), devices);
            let mut seen_d = vec![false; dense.len()];
            let mut seen_a = vec![false; adm.len()];
            for s in &shards {
                for &i in &s.dense_blocks {
                    assert!(!seen_d[i], "dense block {i} assigned twice");
                    seen_d[i] = true;
                }
                for &i in &s.aca_blocks {
                    assert!(!seen_a[i], "aca block {i} assigned twice");
                    seen_a[i] = true;
                }
            }
            assert!(seen_d.iter().all(|&b| b));
            assert!(seen_a.iter().all(|&b| b));
        }
    }

    #[test]
    fn lpt_balances_modeled_cost() {
        let (_, dense, adm) = setup(4096);
        let shards = partition_lpt(&dense, &adm, 16, 4);
        let imb = imbalance(&shards);
        // LPT guarantees <= 4/3 of optimum; block cost granularity is fine
        // enough here that imbalance should be small
        assert!(imb < 1.2, "imbalance {imb}");
    }

    #[test]
    fn sharded_matvec_matches_single_device() {
        let (pts, dense, adm) = setup(2048);
        let cfg = HmxConfig { n: 2048, dim: 2, c_leaf: 64, k: 12, ..HmxConfig::default() };
        let kern = cfg.kernel();
        let engine = NativeEngine;
        let mut rng = crate::util::prng::Xoshiro256::seed(5);
        let x = rng.vector(pts.len());
        // single device reference
        let one = partition_lpt(&dense, &adm, cfg.k, 1);
        let ref_out = sharded_matvec(&pts, kern, &cfg, &dense, &adm, &one, &engine, &x);
        // four simulated devices
        let four = partition_lpt(&dense, &adm, cfg.k, 4);
        let out = sharded_matvec(&pts, kern, &cfg, &dense, &adm, &four, &engine, &x);
        let err = crate::util::rel_err(&out.y, &ref_out.y);
        assert!(err < 1e-12, "sharding changed the product: {err}");
        assert_eq!(out.device_seconds.len(), 4);
    }

    #[test]
    fn sharded_matmat_matches_columnwise_sharded_matvec() {
        let (pts, dense, adm) = setup(2048);
        let cfg = HmxConfig { n: 2048, dim: 2, c_leaf: 64, k: 12, ..HmxConfig::default() };
        let kern = cfg.kernel();
        let engine = NativeEngine;
        let n = pts.len();
        let nrhs = 3;
        let mut rng = crate::util::prng::Xoshiro256::seed(21);
        let x = rng.vector(n * nrhs);
        let shards = partition_lpt(&dense, &adm, cfg.k, 4);
        let block =
            sharded_matmat(&pts, kern, &cfg, &dense, &adm, &shards, &engine, &x, nrhs);
        assert_eq!(block.y.len(), n * nrhs);
        assert_eq!(block.device_seconds.len(), 4);
        for c in 0..nrhs {
            let col = sharded_matvec(
                &pts,
                kern,
                &cfg,
                &dense,
                &adm,
                &shards,
                &engine,
                &x[c * n..(c + 1) * n],
            );
            let err = crate::util::rel_err(&block.y[c * n..(c + 1) * n], &col.y);
            assert!(err < 1e-12, "RHS blocking changed column {c}: {err}");
        }
        // one simulated device must agree with four
        let one = partition_lpt(&dense, &adm, cfg.k, 1);
        let single = sharded_matmat(&pts, kern, &cfg, &dense, &adm, &one, &engine, &x, nrhs);
        assert!(crate::util::rel_err(&block.y, &single.y) < 1e-12);
    }

    #[test]
    fn measured_times_track_modeled_costs() {
        let (pts, dense, adm) = setup(4096);
        let cfg = HmxConfig { n: 4096, dim: 2, c_leaf: 64, k: 16, ..HmxConfig::default() };
        let engine = NativeEngine;
        let x = crate::util::prng::Xoshiro256::seed(9).vector(pts.len());
        let shards = partition_lpt(&dense, &adm, cfg.k, 4);
        let out = sharded_matvec(&pts, cfg.kernel(), &cfg, &dense, &adm, &shards, &engine, &x);
        // measured per-device times should be within ~3x of each other if
        // the cost model is at all sane (loose: single-core timer noise)
        let max = out.device_seconds.iter().cloned().fold(0.0, f64::max);
        let min = out.device_seconds.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min.max(1e-9) < 5.0, "device times {:?}", out.device_seconds);
    }
}
