//! The batching coordinator: dispatches planned batches to a linear-algebra
//! engine.
//!
//! Two engines implement [`BatchEngine`]:
//!
//! * [`NativeEngine`] — the many-core dpp kernels in this crate (always
//!   available; the default).
//! * [`crate::runtime::XlaEngine`] — AOT-compiled XLA executables produced
//!   by the build-time JAX/Pallas layer, executed through PJRT. Shapes
//!   without a matching artifact fall back to the native engine, so a
//!   partially-built artifact set degrades gracefully.

pub mod distributed;

use crate::aca::batched::{
    batched_aca_factors, batched_aca_matmat, batched_aca_matvec, AcaBatch, AcaFactors,
};
use crate::config::{EngineKind, HmxConfig};
use crate::geometry::kernel::Kernel;
use crate::geometry::points::PointSet;
use crate::hmatrix::dense::{batched_dense_matmat, batched_dense_matvec};
use crate::tree::block::WorkItem;
use crate::util::atomic::AtomicF64Vec;
use crate::Result;

/// A batched linear-algebra backend (§5.4's cuBLAS/MAGMA role).
///
/// Not `Send`/`Sync`: the XLA engine owns an `Rc`-backed PJRT client.
/// Engine calls are made from the coordinating thread; the parallelism
/// lives inside the batched kernels.
pub trait BatchEngine {
    /// z|τ += A|τ×σ · x|σ for each dense block (assembled on the fly).
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    );

    /// Fused rank-k ACA + low-rank apply for each admissible block (NP).
    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    );

    /// Rank-k ACA factors for each admissible block (P-mode precompute).
    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors;

    /// Multi-RHS variant of [`BatchEngine::dense_matvec`]: `x` and `z` are
    /// column-major n × nrhs (`x[c * n + j]` is column c, n = points.len()).
    ///
    /// The default loops columns through `dense_matvec` so every engine is
    /// multi-RHS capable; engines with a fused mat-mat kernel override it
    /// (the native engine always, the XLA engine when a `dense_mm`
    /// artifact covers the group's bucket and RHS width). Every
    /// columnwise call is counted under `runtime.matmat_fallback` in
    /// [`crate::metrics::RECORDER`] so missing multi-RHS artifacts stay
    /// observable instead of silent.
    fn dense_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        columnwise_dense_matmat(self, points, kernel, blocks, x, nrhs, z);
    }

    /// Multi-RHS variant of [`BatchEngine::aca_matvec`] (same column-major
    /// layout, columnwise default and fallback counter as
    /// [`BatchEngine::dense_matmat`]).
    #[allow(clippy::too_many_arguments)]
    fn aca_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        columnwise_aca_matmat(self, points, kernel, k, blocks, x, nrhs, z);
    }

    fn name(&self) -> &'static str;
}

/// The columnwise mat-mat fallback behind the [`BatchEngine::dense_matmat`]
/// default: one `dense_matvec` per RHS column. Counted under
/// `runtime.matmat_fallback`; the serving width ladder pads flushes to the
/// fused `dense_mm`/`aca_mm` artifact widths precisely so the serve path
/// never lands here.
pub fn columnwise_dense_matmat<E: BatchEngine + ?Sized>(
    engine: &E,
    points: &PointSet,
    kernel: Kernel,
    blocks: &[WorkItem],
    x: &[f64],
    nrhs: usize,
    z: &AtomicF64Vec,
) {
    crate::metrics::RECORDER.incr(crate::obs::names::RUNTIME_MATMAT_FALLBACK);
    let n = points.len();
    for c in 0..nrhs {
        let zc = AtomicF64Vec::zeros(n);
        engine.dense_matvec(points, kernel, blocks, &x[c * n..(c + 1) * n], &zc);
        for (i, v) in zc.into_vec().into_iter().enumerate() {
            if v != 0.0 {
                z.add(c * n + i, v);
            }
        }
    }
}

/// Columnwise fallback behind [`BatchEngine::aca_matmat`]; see
/// [`columnwise_dense_matmat`].
#[allow(clippy::too_many_arguments)]
pub fn columnwise_aca_matmat<E: BatchEngine + ?Sized>(
    engine: &E,
    points: &PointSet,
    kernel: Kernel,
    k: usize,
    blocks: &[WorkItem],
    x: &[f64],
    nrhs: usize,
    z: &AtomicF64Vec,
) {
    crate::metrics::RECORDER.incr(crate::obs::names::RUNTIME_MATMAT_FALLBACK);
    let n = points.len();
    for c in 0..nrhs {
        let zc = AtomicF64Vec::zeros(n);
        engine.aca_matvec(points, kernel, k, blocks, &x[c * n..(c + 1) * n], &zc);
        for (i, v) in zc.into_vec().into_iter().enumerate() {
            if v != 0.0 {
                z.add(c * n + i, v);
            }
        }
    }
}

/// The native many-core engine.
pub struct NativeEngine;

impl BatchEngine for NativeEngine {
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        batched_dense_matvec(points, kernel, blocks, x, z);
    }

    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        batched_aca_matvec(&AcaBatch { points, kernel, blocks, k }, x, z);
    }

    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors {
        batched_aca_factors(&AcaBatch { points, kernel, blocks, k })
    }

    fn dense_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        batched_dense_matmat(points, kernel, blocks, x, nrhs, z);
    }

    #[allow(clippy::too_many_arguments)]
    fn aca_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        batched_aca_matmat(&AcaBatch { points, kernel, blocks, k }, x, nrhs, z);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The paper's *unbatched* execution mode (Fig 15 comparison): every block
/// is processed by its own sequence of small parallel operations
/// ([`crate::aca::stepwise`]) instead of fused batch kernels. Multi-RHS
/// calls use the columnwise trait defaults — no fusion along the RHS axis
/// either, which is exactly the contrast the Fig 18 bench measures.
pub struct UnbatchedEngine;

impl BatchEngine for UnbatchedEngine {
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        for w in blocks {
            crate::aca::stepwise::stepwise_dense_matvec(points, kernel, w, x, z);
        }
    }

    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        for w in blocks {
            crate::aca::stepwise::stepwise_aca_matvec(points, kernel, k, w, x, z);
        }
    }

    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors {
        // P-mode precompute has no stepwise analogue in the paper (it
        // stores the same factors either way); compute one block at a
        // time through the batched kernel for identical results.
        let mut parts: Vec<AcaFactors> = blocks
            .iter()
            .map(|w| {
                batched_aca_factors(&AcaBatch {
                    points,
                    kernel,
                    blocks: std::slice::from_ref(w),
                    k,
                })
            })
            .collect();
        merge_factors(&mut parts, blocks, k)
    }

    fn name(&self) -> &'static str {
        "native-unbatched"
    }
}

/// Concatenate per-block factor sets into one flat Fig-10 layout.
fn merge_factors(parts: &mut [AcaFactors], blocks: &[WorkItem], k: usize) -> AcaFactors {
    let nb = blocks.len();
    let rows: Vec<usize> = blocks.iter().map(|w| w.rows()).collect();
    let cols: Vec<usize> = blocks.iter().map(|w| w.cols()).collect();
    let row_offsets = crate::dpp::scan::exclusive_scan(&rows);
    let col_offsets = crate::dpp::scan::exclusive_scan(&cols);
    let total_m = row_offsets[nb];
    let total_n = col_offsets[nb];
    let mut u_all = vec![0.0f64; k * total_m];
    let mut v_all = vec![0.0f64; k * total_n];
    let mut ranks = vec![0usize; nb];
    for (b, part) in parts.iter().enumerate() {
        ranks[b] = part.ranks[0];
        let m = rows[b];
        let n = cols[b];
        for l in 0..k {
            u_all[l * total_m + row_offsets[b]..l * total_m + row_offsets[b] + m]
                .copy_from_slice(&part.u_all[l * m..(l + 1) * m]);
            v_all[l * total_n + col_offsets[b]..l * total_n + col_offsets[b] + n]
                .copy_from_slice(&part.v_all[l * n..(l + 1) * n]);
        }
    }
    AcaFactors { u_all, v_all, row_offsets, col_offsets, ranks, k }
}

/// Instantiate the engine selected by `cfg`. With `batching: false`
/// (Fig 15 comparison mode) the native engine runs the paper's unbatched
/// per-block schedule.
pub fn make_engine(cfg: &HmxConfig) -> Result<Box<dyn BatchEngine>> {
    match cfg.engine {
        EngineKind::Native if !cfg.batching => Ok(Box::new(UnbatchedEngine)),
        EngineKind::Native => Ok(Box::new(NativeEngine)),
        EngineKind::Xla => Ok(Box::new(crate::runtime::XlaEngine::new(
            &cfg.artifacts_dir,
            cfg.kernel.name(),
            cfg.dim,
            cfg.k,
        )?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_is_default() {
        let cfg = HmxConfig::default();
        let e = make_engine(&cfg).unwrap();
        assert_eq!(e.name(), "native");
    }

    /// An engine that only implements single-RHS applies, so its mat-mats
    /// go through the trait's columnwise fallback — the XLA engine's
    /// situation whenever no fused `*_mm` artifact covers a group's
    /// bucket/width. Pins that the fallback matches the native engine's
    /// fused `matmat` and that the fallback counter fires.
    struct ColumnwiseOnly(NativeEngine);

    impl BatchEngine for ColumnwiseOnly {
        fn dense_matvec(
            &self,
            points: &PointSet,
            kernel: Kernel,
            blocks: &[WorkItem],
            x: &[f64],
            z: &AtomicF64Vec,
        ) {
            self.0.dense_matvec(points, kernel, blocks, x, z);
        }

        fn aca_matvec(
            &self,
            points: &PointSet,
            kernel: Kernel,
            k: usize,
            blocks: &[WorkItem],
            x: &[f64],
            z: &AtomicF64Vec,
        ) {
            self.0.aca_matvec(points, kernel, k, blocks, x, z);
        }

        fn aca_factors(
            &self,
            points: &PointSet,
            kernel: Kernel,
            k: usize,
            blocks: &[WorkItem],
        ) -> AcaFactors {
            self.0.aca_factors(points, kernel, k, blocks)
        }

        fn name(&self) -> &'static str {
            "columnwise-only"
        }
    }

    #[test]
    fn columnwise_matmat_fallback_matches_native_matmat_and_is_counted() {
        let mut pts = PointSet::halton(1024, 2);
        let _ = crate::morton::morton_sort(&mut pts);
        let tree = crate::tree::block::build_block_tree(&pts, 1.5, 64);
        let kern = Kernel::gaussian();
        let n = pts.len();
        let nrhs = 3;
        let k = 10;
        let x = crate::util::prng::Xoshiro256::seed(8).vector(n * nrhs);
        let native = NativeEngine;
        let fallback = ColumnwiseOnly(NativeEngine);
        let before = crate::metrics::RECORDER.count(crate::obs::names::RUNTIME_MATMAT_FALLBACK);

        let zf = AtomicF64Vec::zeros(n * nrhs);
        fallback.dense_matmat(&pts, kern, &tree.dense, &x, nrhs, &zf);
        let zn = AtomicF64Vec::zeros(n * nrhs);
        native.dense_matmat(&pts, kern, &tree.dense, &x, nrhs, &zn);
        let err = crate::util::rel_err(&zf.into_vec(), &zn.into_vec());
        assert!(err < 1e-13, "dense columnwise fallback diverged from fused matmat: {err}");

        let zf = AtomicF64Vec::zeros(n * nrhs);
        fallback.aca_matmat(&pts, kern, k, &tree.admissible, &x, nrhs, &zf);
        let zn = AtomicF64Vec::zeros(n * nrhs);
        native.aca_matmat(&pts, kern, k, &tree.admissible, &x, nrhs, &zn);
        let err = crate::util::rel_err(&zf.into_vec(), &zn.into_vec());
        assert!(err < 1e-13, "ACA columnwise fallback diverged from fused matmat: {err}");

        let after = crate::metrics::RECORDER.count(crate::obs::names::RUNTIME_MATMAT_FALLBACK);
        assert!(after >= before + 2, "fallback counter must fire: {before} -> {after}");
    }
}
