//! The batching coordinator: dispatches planned batches to a linear-algebra
//! engine.
//!
//! Two engines implement [`BatchEngine`]:
//!
//! * [`NativeEngine`] — the many-core dpp kernels in this crate (always
//!   available; the default).
//! * [`crate::runtime::XlaEngine`] — AOT-compiled XLA executables produced
//!   by the build-time JAX/Pallas layer, executed through PJRT. Shapes
//!   without a matching artifact fall back to the native engine, so a
//!   partially-built artifact set degrades gracefully.

pub mod distributed;

use crate::aca::batched::{
    batched_aca_factors, batched_aca_matmat, batched_aca_matvec, AcaBatch, AcaFactors,
};
use crate::config::{EngineKind, HmxConfig};
use crate::geometry::kernel::Kernel;
use crate::geometry::points::PointSet;
use crate::hmatrix::dense::{batched_dense_matmat, batched_dense_matvec};
use crate::tree::block::WorkItem;
use crate::util::atomic::AtomicF64Vec;
use crate::Result;

/// A batched linear-algebra backend (§5.4's cuBLAS/MAGMA role).
///
/// Not `Send`/`Sync`: the XLA engine owns an `Rc`-backed PJRT client.
/// Engine calls are made from the coordinating thread; the parallelism
/// lives inside the batched kernels.
pub trait BatchEngine {
    /// z|τ += A|τ×σ · x|σ for each dense block (assembled on the fly).
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    );

    /// Fused rank-k ACA + low-rank apply for each admissible block (NP).
    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    );

    /// Rank-k ACA factors for each admissible block (P-mode precompute).
    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors;

    /// Multi-RHS variant of [`BatchEngine::dense_matvec`]: `x` and `z` are
    /// column-major n × nrhs (`x[c * n + j]` is column c, n = points.len()).
    ///
    /// The default loops columns through `dense_matvec` so every engine is
    /// multi-RHS capable (the XLA engine's artifacts are single-RHS);
    /// engines with a fused mat-mat kernel override it.
    fn dense_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        let n = points.len();
        for c in 0..nrhs {
            let zc = AtomicF64Vec::zeros(n);
            self.dense_matvec(points, kernel, blocks, &x[c * n..(c + 1) * n], &zc);
            for (i, v) in zc.into_vec().into_iter().enumerate() {
                if v != 0.0 {
                    z.add(c * n + i, v);
                }
            }
        }
    }

    /// Multi-RHS variant of [`BatchEngine::aca_matvec`] (same column-major
    /// layout and columnwise default as [`BatchEngine::dense_matmat`]).
    #[allow(clippy::too_many_arguments)]
    fn aca_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        let n = points.len();
        for c in 0..nrhs {
            let zc = AtomicF64Vec::zeros(n);
            self.aca_matvec(points, kernel, k, blocks, &x[c * n..(c + 1) * n], &zc);
            for (i, v) in zc.into_vec().into_iter().enumerate() {
                if v != 0.0 {
                    z.add(c * n + i, v);
                }
            }
        }
    }

    fn name(&self) -> &'static str;
}

/// The native many-core engine.
pub struct NativeEngine;

impl BatchEngine for NativeEngine {
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        batched_dense_matvec(points, kernel, blocks, x, z);
    }

    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        batched_aca_matvec(&AcaBatch { points, kernel, blocks, k }, x, z);
    }

    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors {
        batched_aca_factors(&AcaBatch { points, kernel, blocks, k })
    }

    fn dense_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        batched_dense_matmat(points, kernel, blocks, x, nrhs, z);
    }

    #[allow(clippy::too_many_arguments)]
    fn aca_matmat(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        nrhs: usize,
        z: &AtomicF64Vec,
    ) {
        batched_aca_matmat(&AcaBatch { points, kernel, blocks, k }, x, nrhs, z);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The paper's *unbatched* execution mode (Fig 15 comparison): every block
/// is processed by its own sequence of small parallel operations
/// ([`crate::aca::stepwise`]) instead of fused batch kernels. Multi-RHS
/// calls use the columnwise trait defaults — no fusion along the RHS axis
/// either, which is exactly the contrast the Fig 18 bench measures.
pub struct UnbatchedEngine;

impl BatchEngine for UnbatchedEngine {
    fn dense_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        for w in blocks {
            crate::aca::stepwise::stepwise_dense_matvec(points, kernel, w, x, z);
        }
    }

    fn aca_matvec(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
        x: &[f64],
        z: &AtomicF64Vec,
    ) {
        for w in blocks {
            crate::aca::stepwise::stepwise_aca_matvec(points, kernel, k, w, x, z);
        }
    }

    fn aca_factors(
        &self,
        points: &PointSet,
        kernel: Kernel,
        k: usize,
        blocks: &[WorkItem],
    ) -> AcaFactors {
        // P-mode precompute has no stepwise analogue in the paper (it
        // stores the same factors either way); compute one block at a
        // time through the batched kernel for identical results.
        let mut parts: Vec<AcaFactors> = blocks
            .iter()
            .map(|w| {
                batched_aca_factors(&AcaBatch {
                    points,
                    kernel,
                    blocks: std::slice::from_ref(w),
                    k,
                })
            })
            .collect();
        merge_factors(&mut parts, blocks, k)
    }

    fn name(&self) -> &'static str {
        "native-unbatched"
    }
}

/// Concatenate per-block factor sets into one flat Fig-10 layout.
fn merge_factors(parts: &mut [AcaFactors], blocks: &[WorkItem], k: usize) -> AcaFactors {
    let nb = blocks.len();
    let rows: Vec<usize> = blocks.iter().map(|w| w.rows()).collect();
    let cols: Vec<usize> = blocks.iter().map(|w| w.cols()).collect();
    let row_offsets = crate::dpp::scan::exclusive_scan(&rows);
    let col_offsets = crate::dpp::scan::exclusive_scan(&cols);
    let total_m = row_offsets[nb];
    let total_n = col_offsets[nb];
    let mut u_all = vec![0.0f64; k * total_m];
    let mut v_all = vec![0.0f64; k * total_n];
    let mut ranks = vec![0usize; nb];
    for (b, part) in parts.iter().enumerate() {
        ranks[b] = part.ranks[0];
        let m = rows[b];
        let n = cols[b];
        for l in 0..k {
            u_all[l * total_m + row_offsets[b]..l * total_m + row_offsets[b] + m]
                .copy_from_slice(&part.u_all[l * m..(l + 1) * m]);
            v_all[l * total_n + col_offsets[b]..l * total_n + col_offsets[b] + n]
                .copy_from_slice(&part.v_all[l * n..(l + 1) * n]);
        }
    }
    AcaFactors { u_all, v_all, row_offsets, col_offsets, ranks, k }
}

/// Instantiate the engine selected by `cfg`. With `batching: false`
/// (Fig 15 comparison mode) the native engine runs the paper's unbatched
/// per-block schedule.
pub fn make_engine(cfg: &HmxConfig) -> Result<Box<dyn BatchEngine>> {
    match cfg.engine {
        EngineKind::Native if !cfg.batching => Ok(Box::new(UnbatchedEngine)),
        EngineKind::Native => Ok(Box::new(NativeEngine)),
        EngineKind::Xla => Ok(Box::new(crate::runtime::XlaEngine::new(
            &cfg.artifacts_dir,
            cfg.kernel.name(),
            cfg.dim,
            cfg.k,
        )?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_is_default() {
        let cfg = HmxConfig::default();
        let e = make_engine(&cfg).unwrap();
        assert_eq!(e.name(), "native");
    }
}
