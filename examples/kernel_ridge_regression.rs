//! END-TO-END driver: kernel ridge regression on a synthetic dataset,
//! exercising the full three-layer stack on a real small workload.
//!
//! Pipeline: Halton training inputs + noisy function observations
//!   → H-matrix for (A_{φ,Y×Y}) with the configured engine
//!     (pass --engine xla after `make artifacts` to run the batched
//!     numerics through the AOT-compiled JAX/Pallas executables via PJRT)
//!   → CG solve of (A + σ²I) α = y   (the paper's Eq. (1) with ridge term)
//!   → prediction on held-out test points, train/test RMSE
//!   → per-phase timing + CG residual curve.
//!
//! Run:  cargo run --release --example kernel_ridge_regression -- \
//!           [--n 8192] [--d 2] [--sigma2 1e-3] [--engine xla]
//!
//! The EXPERIMENTS.md "End-to-end validation" section records a reference
//! run of this example.

use hmx::config::{EngineKind, HmxConfig, KernelKind};
use hmx::prelude::*;
use hmx::solver::cg::RegularizedHOp;
use hmx::util::cli::Args;
use hmx::util::prng::Xoshiro256;
use std::time::Instant;

/// Ground-truth function to regress (smooth, multiscale).
fn f_true(p: &[f64]) -> f64 {
    let s: f64 = p.iter().sum();
    let r2: f64 = p.iter().map(|x| (x - 0.5) * (x - 0.5)).sum();
    (3.0 * s).sin() + (-4.0 * r2).exp()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get("n", 1usize << 13);
    let dim = args.get("d", 2usize);
    let sigma2 = args.get("sigma2", 1e-3f64);
    let noise = args.get("noise", 1e-2f64);
    let n_test = args.get("n-test", 1024usize);
    let engine = match args.get_str("engine", "native").as_str() {
        "xla" => EngineKind::Xla,
        _ => EngineKind::Native,
    };
    let cfg = HmxConfig {
        n,
        dim,
        k: args.get("k", 16usize),
        c_leaf: args.get("c-leaf", 256usize),
        kernel: KernelKind::from_name(&args.get_str("kernel", "gaussian")).unwrap(),
        engine,
        // P mode by default: CG re-applies the operator many times
        precompute: !args.has("no-precompute"),
        artifacts_dir: args.get_str("artifacts", "artifacts"),
        ..HmxConfig::default()
    };

    // --- dataset: y_i = f(x_i) + ε ---
    let train = PointSet::halton(n, dim);
    let mut rng = Xoshiro256::seed(args.get("seed", 42u64));
    let y_obs: Vec<f64> =
        (0..n).map(|i| f_true(&train.point(i)) + noise * rng.normal()).collect();

    // --- H-matrix construction ---
    let t_setup = Instant::now();
    let h = HMatrix::build(train.clone(), &cfg)?;
    let setup_s = t_setup.elapsed().as_secs_f64();
    println!(
        "[setup]   n={n} d={dim} kernel={} engine={} precompute={} : {setup_s:.3}s",
        cfg.kernel.name(),
        h.engine_name(),
        h.is_precomputed()
    );
    println!(
        "[setup]   {} admissible / {} dense blocks, compression {:.4}",
        h.stats.admissible_blocks,
        h.stats.dense_blocks,
        h.compression_ratio()
    );

    // --- CG solve of (A + σ²I) α = y ---
    let op = RegularizedHOp::new(&h, sigma2);
    let t_solve = Instant::now();
    let res = cg_solve(
        &op,
        &y_obs,
        CgOptions { max_iter: args.get("max-iter", 300usize), tol: args.get("tol", 1e-8f64) },
    );
    let solve_s = t_solve.elapsed().as_secs_f64();
    println!(
        "[solve]   CG {} in {} iters, residual {:.3e}, {:.3}s ({:.1} ms/iter)",
        if res.converged { "converged" } else { "NOT converged" },
        res.iterations,
        res.residual,
        solve_s,
        1e3 * solve_s / res.iterations.max(1) as f64
    );
    // residual curve (every ~8th iteration)
    let curve: Vec<String> = res
        .history
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 8 == 0)
        .map(|(i, r)| format!("{i}:{r:.1e}"))
        .collect();
    println!("[solve]   residual curve: {}", curve.join(" "));

    // --- prediction: f̂(x*) = Σ_i α_i φ(x*, x_i) ---
    let alpha = &res.x;
    let kern = cfg.kernel();
    let predict = |p: &[f64]| -> f64 {
        let mut acc = 0.0;
        for i in 0..n {
            let pt = train.point(i);
            acc += alpha[i] * kern.eval_coords(p, &pt);
        }
        acc
    };
    let t_pred = Instant::now();
    // train RMSE (on a subsample for speed)
    let stride = (n / 2048).max(1);
    let mut train_se = 0.0;
    let mut train_cnt = 0usize;
    for i in (0..n).step_by(stride) {
        let p = train.point(i);
        let e = predict(&p) - f_true(&p);
        train_se += e * e;
        train_cnt += 1;
    }
    // test RMSE on fresh random points
    let mut test_rng = Xoshiro256::seed(999);
    let mut test_se = 0.0;
    for _ in 0..n_test {
        let p: Vec<f64> = (0..dim).map(|_| test_rng.next_f64()).collect();
        let e = predict(&p) - f_true(&p);
        test_se += e * e;
    }
    println!(
        "[predict] train RMSE {:.4e} (on {} pts), test RMSE {:.4e} (on {} pts), {:.3}s",
        (train_se / train_cnt as f64).sqrt(),
        train_cnt,
        (test_se / n_test as f64).sqrt(),
        n_test,
        t_pred.elapsed().as_secs_f64()
    );

    println!("[phases]");
    for (phase, total, count) in hmx::metrics::RECORDER.snapshot() {
        println!("  {phase:<28} {:>9.4}s ({count}x)", total.as_secs_f64());
    }
    Ok(())
}
