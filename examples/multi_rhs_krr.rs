//! END-TO-END driver: multi-output kernel ridge regression through the
//! multi-RHS pipeline — the serving-shaped workload the batched mat-mat
//! engine exists for.
//!
//! Pipeline: Halton training inputs + q noisy target functions
//!   → ONE H-matrix for A_{φ,Y×Y}
//!   → block-CG solve of (A + σ²I) [α₁ … α_q] = [y₁ … y_q]
//!     (one batched H-mat-mat per iteration instead of q mat-vecs)
//!   → per-output train RMSE, plus timing against q single-RHS CG solves.
//!
//! Run:  cargo run --release --example multi_rhs_krr -- \
//!           [--n 8192] [--d 2] [--q 16] [--sigma2 1e-3] [--budget-mb MB]
//!
//! With `--budget-mb` the built operator is compressed to the byte budget
//! (operator-wide waterfilled truncation + mixed-precision storage, see
//! `hmx::compress`) BEFORE the fit: the whole multi-RHS solve then runs
//! on the governed operator, and the achieved bytes/error are reported.

use hmx::config::{HmxConfig, KernelKind};
use hmx::prelude::*;
use hmx::solver::cg::RegularizedHOp;
use hmx::util::cli::Args;
use hmx::util::prng::Xoshiro256;
use std::time::Instant;

/// Family of ground-truth functions to regress (one per output channel).
fn f_true(p: &[f64], channel: usize) -> f64 {
    let s: f64 = p.iter().sum();
    let r2: f64 = p.iter().map(|x| (x - 0.5) * (x - 0.5)).sum();
    let w = 1.0 + channel as f64 * 0.5;
    (w * 3.0 * s).sin() + (-4.0 * w * r2).exp()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let trace_out = args.get_str("trace-out", "");
    if !trace_out.is_empty() {
        hmx::obs::trace::enable();
    }
    let n = args.get("n", 1usize << 13);
    let dim = args.get("d", 2usize);
    let q = args.get("q", 16usize);
    let sigma2 = args.get("sigma2", 1e-3f64);
    let noise = args.get("noise", 1e-2f64);
    let cfg = HmxConfig {
        n,
        dim,
        k: args.get("k", 16usize),
        c_leaf: args.get("c-leaf", 256usize),
        kernel: KernelKind::from_name(&args.get_str("kernel", "gaussian")).unwrap(),
        precompute: !args.has("no-precompute"),
        ..HmxConfig::default()
    };

    // --- dataset: q output channels over shared inputs (column-major) ---
    let train = PointSet::halton(n, dim);
    let mut rng = Xoshiro256::seed(args.get("seed", 42u64));
    let mut b = vec![0.0; n * q];
    for c in 0..q {
        for i in 0..n {
            b[c * n + i] = f_true(&train.point(i), c) + noise * rng.normal();
        }
    }

    let t0 = Instant::now();
    let mut h = HMatrix::build(train.clone(), &cfg)?;
    println!(
        "built H-matrix: n={n} d={dim} engine={} compression={:.4} ({:.2?})",
        h.engine_name(),
        h.compression_ratio(),
        t0.elapsed()
    );

    // --- optional memory budget: fit under it, report error + bytes ---
    if args.has("budget-mb") && !h.is_precomputed() {
        println!("--budget-mb ignored: NP mode holds no factor storage to budget");
    } else if args.has("budget-mb") {
        let budget = args.get("budget-mb", 16usize) * (1 << 20);
        let mut rng_probe = Xoshiro256::seed(1234);
        let xp = rng_probe.vector(n);
        let y_ref = h.matvec(&xp)?;
        let stats = h.compress(&CompressConfig::bytes(budget))?;
        let achieved = hmx::util::rel_err(&h.matvec(&xp)?, &y_ref);
        println!(
            "compressed under {budget} B budget: factor bytes {} -> {} \
             (retained {:.3}, {}/{} blocks f32), matvec rel err {achieved:.3e} \
             (predicted {:.3e})",
            stats.bytes_before,
            stats.bytes_after,
            stats.retained_fraction(),
            stats.f32_blocks,
            stats.blocks,
            stats.predicted_rel_err,
        );
        if stats.bytes_after > budget {
            println!("warning: rank-1 floor exceeds the budget; got as close as possible");
        }
    }

    // --- block solve: all q channels through one batched operator ---
    let op = RegularizedHBlockOp::new(&h, sigma2);
    let opts = BlockCgOptions { max_iter: args.get("max-iter", 500usize), tol: 1e-8 };
    let t1 = Instant::now();
    let res = block_cg_solve(&op, &b, q, opts);
    let t_block = t1.elapsed();
    println!(
        "block-CG: q={q} iters={} converged={} worst_rel={:.2e} ({t_block:.2?})",
        res.iterations,
        res.converged,
        res.residuals.iter().cloned().fold(0.0f64, f64::max),
    );

    // --- contrast: the q single-RHS solves serving did before ---
    let single_op = RegularizedHOp::new(&h, sigma2);
    let t2 = Instant::now();
    let mut single_iters = 0usize;
    for c in 0..q {
        let r = cg_solve(&single_op, &b[c * n..(c + 1) * n], CgOptions {
            max_iter: opts.max_iter,
            tol: opts.tol,
        });
        single_iters += r.iterations;
    }
    let t_single = t2.elapsed();
    println!(
        "columnwise CG: {single_iters} total iters ({t_single:.2?}); block speedup {:.2}x",
        t_single.as_secs_f64() / t_block.as_secs_f64().max(f64::MIN_POSITIVE)
    );

    // --- fit quality: train RMSE per channel, prediction y_hat = A α ---
    let mut ws = MatvecWorkspace::with_capacity(n, q);
    let fitted = h.matmat_with(&res.x, q, &mut ws)?;
    for c in [0, q / 2, q - 1] {
        let mut se = 0.0;
        for i in 0..n {
            let diff = fitted[c * n + i] - b[c * n + i];
            se += diff * diff;
        }
        println!("channel {c}: train RMSE {:.3e}", (se / n as f64).sqrt());
    }

    // end-of-run observability dump: build/matvec phase totals, solver
    // iteration histograms, final-residual gauges
    let snap = hmx::obs::MetricsSnapshot::capture();
    if args.has("obs-json") {
        println!("{}", snap.to_json());
    } else {
        println!("observability snapshot:");
        for s in &snap.phases {
            println!(
                "  phase {:<22} total {:.4}s  count {}  mean {:.6}s",
                s.phase,
                s.total.as_secs_f64(),
                s.count,
                s.mean.as_secs_f64()
            );
        }
        for h in &snap.histograms {
            println!(
                "  hist  {:<22} count {:<6} p50 {:<8} p99 {:<8} max {}",
                h.name, h.count, h.p50, h.p99, h.max
            );
        }
        for (name, _, v) in &snap.counters {
            println!("  ctr   {name:<22} {v}");
        }
        for (name, _, v) in &snap.gauges {
            println!("  gauge {name:<22} {v}");
        }
    }
    if !trace_out.is_empty() {
        let spans = hmx::obs::write_chrome_trace(std::path::Path::new(&trace_out))?;
        println!("wrote {spans} spans to {trace_out} (chrome://tracing / Perfetto)");
    }
    Ok(())
}
