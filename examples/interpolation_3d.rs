//! Mesh-free kernel interpolation in 3D with the Matérn kernel — the
//! paper's second motivating application (first-order convergent function
//! interpolation, §6.2, Fasshauer Thm 14.5 setting).
//!
//! Interpolates f on Halton points in [0,1]^3 by solving A_{φ_M} c = f|_Y
//! with CG over the H-mat-vec, then reports the sup/rms interpolation
//! error on held-out points for a sweep of ACA ranks k.
//!
//! Run:  cargo run --release --example interpolation_3d -- [--n 4096]

use hmx::config::{HmxConfig, KernelKind};
use hmx::prelude::*;
use hmx::solver::cg::RegularizedHOp;
use hmx::util::cli::Args;
use hmx::util::prng::Xoshiro256;
use std::time::Instant;

fn f_true(p: &[f64]) -> f64 {
    (2.0 * p[0]).sin() * (3.0 * p[1]).cos() + p[2] * p[2]
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get("n", 1usize << 12);
    let dim = 3usize;
    let train = PointSet::halton(n, dim);
    let f_obs: Vec<f64> = (0..n).map(|i| f_true(&train.point(i))).collect();
    // small ridge for CG conditioning (kernel interpolation matrices with
    // Matérn kernels are severely ill-conditioned; σ² trades a little bias
    // for a solvable system — standard practice)
    let sigma2 = args.get("sigma2", 1e-6f64);
    let n_test = args.get("n-test", 512usize);

    println!("Matérn interpolation, n={n}, d=3, rank sweep:");
    println!("{:>4} {:>10} {:>12} {:>12} {:>8}", "k", "setup(s)", "rms_err", "sup_err", "iters");
    for k in [8usize, 16, 32] {
        let cfg = HmxConfig {
            n,
            dim,
            k,
            c_leaf: args.get("c-leaf", 128usize),
            kernel: KernelKind::Matern,
            // P mode: CG re-applies the operator hundreds of times, so
            // pre-computing the ACA factors pays for itself immediately
            precompute: true,
            ..HmxConfig::default()
        };
        let t0 = Instant::now();
        let h = HMatrix::build(train.clone(), &cfg)?;
        let setup = t0.elapsed().as_secs_f64();
        let op = RegularizedHOp::new(&h, sigma2);
        let res = cg_solve(&op, &f_obs, CgOptions { max_iter: 600, tol: 1e-7 });
        let kern = cfg.kernel();
        let mut rng = Xoshiro256::seed(123);
        let mut se = 0.0;
        let mut sup: f64 = 0.0;
        for _ in 0..n_test {
            let p: Vec<f64> = (0..dim).map(|_| rng.next_f64()).collect();
            let mut pred = 0.0;
            for i in 0..n {
                pred += res.x[i] * kern.eval_coords(&p, &train.point(i));
            }
            let e = (pred - f_true(&p)).abs();
            se += e * e;
            sup = sup.max(e);
        }
        println!(
            "{k:>4} {setup:>10.3} {:>12.4e} {:>12.4e} {:>8}",
            (se / n_test as f64).sqrt(),
            sup,
            res.iterations
        );
    }
    println!("(errors should plateau once k exceeds the ACA accuracy needed\n for the interpolation problem; the plateau is the meshfree\n interpolation error of the Matérn kernel itself)");
    Ok(())
}
