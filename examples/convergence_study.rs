//! Fig 11 as a runnable example: relative error of the H-mat-vec against
//! the exact dense product for growing ACA rank k, for Gaussian and
//! Matérn kernels in d = 2 and 3 (exponential convergence expected).
//!
//! Run:  cargo run --release --example convergence_study -- [--n 8192]
//! (paper: N = 32768, C_leaf = 256, η = 1.5 — pass --n 32768 to match)

use hmx::config::{HmxConfig, KernelKind};
use hmx::prelude::*;
use hmx::util::prng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let args = hmx::util::cli::Args::parse();
    let n = args.get("n", 1usize << 13);
    let ks = [1usize, 2, 4, 8, 12, 16, 24, 32];
    println!("relative H-matvec error vs ACA rank (N={n}, C_leaf=256, eta=1.5)");
    println!("{:>8} {:>9} {:>12} {:>12}", "kernel", "d", "k", "rel_err");
    for dim in [2usize, 3] {
        for kernel in [KernelKind::Gaussian, KernelKind::Matern] {
            let pts = PointSet::halton(n, dim);
            let base = HmxConfig { n, dim, kernel, c_leaf: 256, ..HmxConfig::default() };
            let exact = DenseOperator::new(pts.clone(), base.kernel());
            let x = Xoshiro256::seed(1).vector(n);
            let want = exact.matvec(&x);
            for &k in &ks {
                let cfg = HmxConfig { k, ..base.clone() };
                let h = HMatrix::build(pts.clone(), &cfg)?;
                let err = hmx::util::rel_err(&h.matvec(&x)?, &want);
                println!("{:>8} {:>9} {:>12} {:>12.4e}", kernel.name(), dim, k, err);
            }
        }
    }
    Ok(())
}
