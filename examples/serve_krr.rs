//! END-TO-END driver: multi-tenant KRR serving through `hmx::serve`.
//!
//! Pipeline, per tenant:
//!   Halton training inputs + q noisy target channels
//!     → register ONE H-matrix operator in the `OperatorRegistry`
//!       (built on its dedicated executor thread; engines are not `Send`)
//!     → OFFLINE fit of the weight block [α₁ … α_q]: the block solver's
//!       applies are routed THROUGH the serving layer as `submit_async`
//!       futures (one reactor thread, all q columns in flight at once, so
//!       the batcher coalesces the solver's own applies into multi-RHS
//!       batches) — block CG for even tenants, block BiCGSTAB for odd ones
//!     → ONLINE serving: C client threads × R predict requests each on a
//!       weighted fair-queue lane (`<id>/online`, weight 2) next to the
//!       fit lane (`<id>/fit`, weight 1), coalesced by the DynamicBatcher;
//!       overload is shed, not queued
//!   … then per-tenant occupancy/latency telemetry and the global
//!   `serve.*` phase stats.
//!
//! Run:  cargo run --release --example serve_krr -- \
//!           [--n 4096] [--tenants 2] [--q 4] [--clients 4] [--requests 8] \
//!           [--sigma2 1e-3] [--max-batch 32] [--max-wait-ms 5] [--max-iter 100] \
//!           [--budget-mb MB] [--deadline-ms MS] [--trace-out PATH] \
//!           [--slo-p99-ms MS] [--slo-window-s S] [--slo-budget FRAC]
//!
//! Every tenant gets a declarative latency SLO (p99 target, window, error
//! budget); the end-of-run `registry.observe()` reports each tenant's
//! error-budget burn rate. With `--trace-out` the Chrome trace carries
//! request-scoped flow links: each predict reads as one connected
//! submit → queue → apply → scatter timeline across threads.
//!
//! With `--budget-mb` the registry runs under a `MemoryGovernor`: tenant
//! admissions must fit the cross-tenant P-mode factor-byte ceiling, with
//! over-budget builds triggering in-place recompression of the coldest
//! tenants and idle-LRU eviction (all decisions reported at the end).
//!
//! The registry runs under its supervision watchdog for the whole run
//! (dead/wedged executors would be respawned from their build recipes),
//! and `--deadline-ms` gives every online predict a per-request budget:
//! requests that cannot be served in time resolve `DeadlineExceeded`
//! instead of riding a stale backlog.

use hmx::config::{HmxConfig, KernelKind};
use hmx::obs::names;
use hmx::obs::slo::SloConfig;
use hmx::prelude::*;
use hmx::util::cli::Args;
use hmx::util::prng::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Family of ground-truth functions to regress (one per output channel).
fn f_true(p: &[f64], channel: usize) -> f64 {
    let s: f64 = p.iter().sum();
    let r2: f64 = p.iter().map(|x| (x - 0.5) * (x - 0.5)).sum();
    let w = 1.0 + channel as f64 * 0.5;
    (w * 3.0 * s).sin() + (-4.0 * w * r2).exp()
}

/// (A + σ²I) where the A-apply goes through the serving layer: every
/// column is one async submission, all in flight before any is awaited,
/// so the batcher coalesces the solver's own applies into multi-RHS
/// batches (occupancy ≈ q during the fit) from this ONE reactor thread.
struct ServedRegularizedOp {
    client: BatcherClient,
    sigma2: f64,
}

impl BlockLinOp for ServedRegularizedOp {
    fn apply_block(&self, x: &[f64], nrhs: usize) -> Vec<f64> {
        let n = self.client.n();
        let mut futures = Vec::with_capacity(nrhs);
        for c in 0..nrhs {
            let col = &x[c * n..(c + 1) * n];
            // bounded-queue backpressure during the fit: back off and
            // resubmit instead of aborting (the online clients shed)
            let fut = loop {
                match self.client.submit_async(col.to_vec()) {
                    Ok(f) => break f,
                    Err(ServeError::Overloaded) => {
                        std::thread::sleep(Duration::from_micros(200))
                    }
                    Err(e) => panic!("serve submit failed: {e}"),
                }
            };
            futures.push(fut);
        }
        let mut y = Vec::with_capacity(n * nrhs);
        for f in futures {
            y.extend(block_on(f).expect("serve apply failed"));
        }
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.sigma2 * xi;
        }
        y
    }

    fn dim(&self) -> usize {
        self.client.n()
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let trace_out = args.get_str("trace-out", "");
    if !trace_out.is_empty() {
        hmx::obs::trace::enable();
    }
    let n = args.get("n", 1usize << 12);
    let dim = args.get("d", 2usize);
    let tenants = args.get("tenants", 2usize);
    let q = args.get("q", 4usize);
    let clients = args.get("clients", 4usize);
    let requests = args.get("requests", 8usize);
    let sigma2 = args.get("sigma2", 1e-3f64);
    let noise = args.get("noise", 1e-2f64);
    let max_iter = args.get("max-iter", 100usize);
    let serve_cfg = ServeConfig {
        max_batch: args.get("max-batch", 32usize),
        max_wait: Duration::from_millis(args.get("max-wait-ms", 5u64)),
        queue_capacity: args.get("queue-capacity", 1024usize),
        ..ServeConfig::default()
    };

    let deadline_ms = args.get("deadline-ms", 0u64);

    let registry = Arc::new(if args.has("budget-mb") {
        let budget = args.get("budget-mb", 64usize) * (1 << 20);
        println!("memory governor: cross-tenant factor budget {budget} B");
        OperatorRegistry::with_governor(MemoryGovernor::with_budget(budget))
    } else {
        OperatorRegistry::new()
    });
    // background supervision: heartbeat checks + respawn-from-recipe for
    // any executor that dies or wedges while the run is serving
    let watchdog = registry.spawn_watchdog(Duration::from_millis(250));
    for t in 0..tenants {
        let id = format!("tenant-{t}");
        let kernel = if t % 2 == 0 { KernelKind::Gaussian } else { KernelKind::Matern };
        let cfg = HmxConfig {
            n,
            dim,
            k: args.get("k", 16usize),
            c_leaf: args.get("c-leaf", 256usize),
            kernel,
            precompute: !args.has("no-precompute"),
            ..HmxConfig::default()
        };
        let train = PointSet::halton(n, dim);

        // --- register: builds the operator on its executor thread; under
        // a governor the admission may recompress/evict colder tenants ---
        let t0 = Instant::now();
        let handle = registry.get_or_build(&id, train.clone(), &cfg, serve_cfg.clone())?;
        println!(
            "[{id}] registered: n={n} kernel={} engine={} compression={:.4} \
             factor-bytes={} (registry total {}) ({:.2?})",
            cfg.kernel.name(),
            handle.meta().engine,
            handle.meta().compression_ratio,
            handle.meta().build_stats.factor_bytes,
            registry.factor_bytes(),
            t0.elapsed()
        );
        // declarative latency SLO: every registry.observe() differentials
        // the tenant's serve.latency series into error-budget burn-rate
        // gauges, and sustained burn raises the tenant's health floor
        // (brown-out shedding driven by the SLO, not just queue depth)
        let slo = SloConfig {
            p99_target: Duration::from_millis(args.get("slo-p99-ms", 250u64)),
            window: Duration::from_secs(args.get("slo-window-s", 60u64)),
            error_budget: args.get("slo-budget", 0.05f64),
        };
        registry.set_slo(&id, slo).expect("SLO config rejected");
        println!(
            "[{id}] slo: p99 <= {:?} over {:?} (error budget {:.1}%)",
            slo.p99_target,
            slo.window,
            slo.error_budget * 100.0
        );

        // --- q noisy target channels over the shared inputs ---
        let mut rng = Xoshiro256::seed(args.get("seed", 42u64) + t as u64);
        let mut b = vec![0.0; n * q];
        for c in 0..q {
            for i in 0..n {
                b[c * n + i] = f_true(&train.point(i), c) + noise * rng.normal();
            }
        }

        // --- offline fit THROUGH the serving layer, on its own weighted
        // fair-queue lane (so its wait series is separable from online) ---
        let op = ServedRegularizedOp {
            client: handle.for_tenant(&format!("{id}/fit"), 1.0),
            sigma2,
        };
        let t1 = Instant::now();
        let (solver, alpha, iters, converged) = if t % 2 == 0 {
            let res = block_cg_solve(&op, &b, q, BlockCgOptions { max_iter, tol: 1e-6 });
            ("block-CG", res.x, res.iterations, res.converged)
        } else {
            let res =
                block_bicgstab_solve(&op, &b, q, BlockBiCgStabOptions { max_iter, tol: 1e-6 });
            ("block-BiCGSTAB", res.x, res.iterations, res.converged)
        };
        let fit_occupancy = handle.stats().mean_occupancy();
        println!(
            "[{id}] {solver}: q={q} iters={iters} converged={converged} \
             fit-occupancy={fit_occupancy:.2} ({:.2?})",
            t1.elapsed()
        );

        // --- online serving: C concurrent clients, coalesced predicts ---
        handle.stats().reset(); // separate fit telemetry from serving telemetry
        let alpha = Arc::new(alpha);
        let targets = Arc::new(b);
        let t2 = Instant::now();
        let mut joins = Vec::new();
        for client in 0..clients {
            // online lane: twice the fit lane's fair-queue weight, its own
            // per-tenant `serve.wait` series under label `<id>/online`
            let mut lane = handle.for_tenant(&format!("{id}/online"), 2.0);
            if deadline_ms > 0 {
                lane = lane.with_deadline(Duration::from_millis(deadline_ms));
            }
            let alpha = Arc::clone(&alpha);
            let targets = Arc::clone(&targets);
            joins.push(std::thread::spawn(move || -> (usize, f64) {
                let mut served = 0usize;
                let mut worst_rmse = 0.0f64;
                for r in 0..requests {
                    let c = (client + r) % q;
                    match lane.predict(&alpha[c * n..(c + 1) * n]) {
                        Ok(yhat) => {
                            // fitted values: ŷ + σ²α should reproduce the targets
                            let mut se = 0.0;
                            for i in 0..n {
                                let d =
                                    yhat[i] + sigma2 * alpha[c * n + i] - targets[c * n + i];
                                se += d * d;
                            }
                            worst_rmse = worst_rmse.max((se / n as f64).sqrt());
                            served += 1;
                        }
                        Err(ServeError::Overloaded) => {} // shed: client backs off
                        Err(ServeError::DeadlineExceeded) => {} // budget spent queueing
                        Err(e) => panic!("serving failed: {e}"),
                    }
                }
                (served, worst_rmse)
            }));
        }
        let mut served_total = 0usize;
        let mut worst_rmse = 0.0f64;
        for j in joins {
            let (served, rmse) = j.join().expect("client thread panicked");
            served_total += served;
            worst_rmse = worst_rmse.max(rmse);
        }
        let elapsed = t2.elapsed().as_secs_f64();
        let snap = handle.stats().snapshot();
        println!(
            "[{id}] served {served_total}/{} predicts in {elapsed:.3}s \
             ({:.1} req/s), worst train RMSE {worst_rmse:.3e}",
            clients * requests,
            served_total as f64 / elapsed.max(f64::MIN_POSITIVE),
        );
        println!("[{id}] telemetry: {snap}");
    }

    if let Some(gov) = registry.governor() {
        let snap = gov.snapshot();
        println!(
            "governor: {} / {} B in use, {} recompressions, {} evictions, {} rejections",
            snap.bytes_in_use,
            snap.budget_bytes,
            snap.recompressions,
            snap.evictions,
            snap.rejections
        );
    }
    println!("registry health at end of run: {}", registry.health());
    watchdog.stop();
    // end-of-run observability dump via the registry (refreshes the
    // `serve.health` gauge, then captures every tenant's latency
    // histograms, governor counters, and queue-depth gauges)
    let snap = registry.observe();
    if args.has("obs-json") {
        println!("{}", snap.to_json());
    } else {
        println!("observability snapshot:");
        for s in &snap.phases {
            if s.phase.starts_with("serve.") || s.phase.starts_with("governor.") {
                println!(
                    "  phase {:<18} total {:.4}s  count {}  mean {:.6}s",
                    s.phase,
                    s.total.as_secs_f64(),
                    s.count,
                    s.mean.as_secs_f64()
                );
            }
        }
        for h in &snap.histograms {
            let label = if h.tenant.is_empty() {
                h.name.clone()
            } else {
                format!("{}{{tenant={}}}", h.name, h.tenant)
            };
            println!(
                "  hist  {:<34} count {:<6} p50 {:<10} p99 {:<10} max {}",
                label, h.count, h.p50, h.p99, h.max
            );
        }
        for (name, tenant, v) in &snap.counters {
            let label =
                if tenant.is_empty() { name.clone() } else { format!("{name}{{tenant={tenant}}}") };
            println!("  ctr   {label:<34} {v}");
        }
        for (name, tenant, v) in &snap.gauges {
            let label =
                if tenant.is_empty() { name.clone() } else { format!("{name}{{tenant={tenant}}}") };
            println!("  gauge {label:<34} {v}");
        }
    }
    // per-tenant SLO verdicts from the burn-rate gauges the observe()
    // above refreshed (burn < 1 = sustainable; >= 1 burns the budget)
    for (name, tenant, burn) in &snap.gauges {
        if name.as_str() == names::SLO_BURN_RATE {
            let remaining = snap
                .gauges
                .iter()
                .find(|(n2, t2, _)| {
                    n2.as_str() == names::SLO_BUDGET_REMAINING && t2 == tenant
                })
                .map(|(_, _, v)| *v)
                .unwrap_or(f64::NAN);
            println!(
                "slo[{tenant}]: burn rate {burn:.2}, error budget remaining {:.0}%",
                remaining * 100.0
            );
        }
    }
    if !trace_out.is_empty() {
        let spans = hmx::obs::write_chrome_trace(std::path::Path::new(&trace_out))?;
        println!("wrote {spans} spans to {trace_out} (chrome://tracing / Perfetto)");
    }
    Ok(())
}
