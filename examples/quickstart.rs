//! Quickstart: build an H-matrix for a Gaussian kernel on Halton points,
//! run the fast mat-vec, and check the error against the exact dense
//! product — the paper's model problem (§6.2) in ~30 lines.
//!
//! Run:  cargo run --release --example quickstart [-- --n 16384 --d 2]

use hmx::config::HmxConfig;
use hmx::prelude::*;
use hmx::util::cli::Args;
use hmx::util::prng::Xoshiro256;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cfg = HmxConfig {
        n: args.get("n", 1usize << 14),
        dim: args.get("d", 2usize),
        k: args.get("k", 16usize),
        c_leaf: args.get("c-leaf", 256usize),
        ..HmxConfig::default()
    };

    // 1. the model workload: Halton points on the unit square/cube
    let points = PointSet::halton(cfg.n, cfg.dim);

    // 2. H-matrix construction (Morton sort -> block tree -> batch plans)
    let t0 = Instant::now();
    let h = HMatrix::build(points.clone(), &cfg)?;
    println!(
        "setup:  n={} d={} in {:.3}s ({} admissible + {} dense blocks, compression {:.3})",
        cfg.n,
        cfg.dim,
        t0.elapsed().as_secs_f64(),
        h.stats.admissible_blocks,
        h.stats.dense_blocks,
        h.compression_ratio()
    );

    // 3. fast mat-vec
    let x = Xoshiro256::seed(7).vector(cfg.n);
    let t1 = Instant::now();
    let y = h.matvec(&x)?;
    println!("matvec: {:.3}s, |y|_2 = {:.6}", t1.elapsed().as_secs_f64(), hmx::util::norm2(&y));

    // 4. verify against the exact dense product (small n only)
    if cfg.n <= 1 << 15 {
        let exact = DenseOperator::new(points, cfg.kernel());
        let err = hmx::util::rel_err(&y, &exact.matvec(&x));
        println!("error:  |Hx - Ax| / |Ax| = {err:.3e}  (rank k = {})", cfg.k);
    }
    Ok(())
}
