"""AOT pipeline tests: lowering produces loadable HLO text."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_enable_x64", True)


class TestLowering:
    def test_dense_mv_lowers_to_hlo_text(self):
        lowered = aot.lower_dense_mv("gaussian", 2, 64, 2)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f64" in text

    def test_aca_mv_lowers_to_hlo_text(self):
        lowered = aot.lower_aca_mv("gaussian", 2, 64, 4, 2)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        # the fori_loop lowers to a while op
        assert "while" in text

    def test_aca_factors_lowers_with_tuple_output(self):
        lowered = aot.lower_aca_factors("matern", 3, 64, 4, 2)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "tuple" in text.lower()

    def test_lowered_dense_executes_like_model(self):
        """Executing the lowered computation via jax matches model.dense_mv
        (sanity that lowering captured the right program)."""
        rng = np.random.default_rng(0)
        tau = jnp.asarray(rng.uniform(size=(2, 64, 2)))
        sigma = jnp.asarray(rng.uniform(size=(2, 64, 2)))
        x = jnp.asarray(rng.uniform(-1, 1, size=(2, 64)))
        lowered = aot.lower_dense_mv("gaussian", 2, 64, 2)
        compiled = lowered.compile()
        got = compiled(tau, sigma, x)
        want = model.dense_mv(tau, sigma, x, kernel="gaussian")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


class TestCliEndToEnd:
    def test_aot_cli_writes_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                str(out),
                "--kernels",
                "gaussian",
                "--dims",
                "2",
                "--k",
                "4",
                "--dense-buckets",
                "64",
                "--aca-buckets",
                "64",
                "--batch",
                "2",
            ],
            check=True,
            cwd=str(aot.os.path.dirname(aot.os.path.dirname(aot.__file__))),
        )
        manifest = (out / "manifest.tsv").read_text()
        lines = [l for l in manifest.strip().splitlines() if not l.startswith("#")]
        assert len(lines) == 3  # dense_mv + aca_mv + aca_factors
        for line in lines:
            name, fname = line.split("\t")[:2]
            assert (out / fname).exists(), fname
            head = (out / fname).read_text()[:200]
            assert "HloModule" in head
