"""L2 tests: batched dense mat-vec and batched ACA graphs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def separated_clusters(rng, b, m, n, d, gap=0.6):
    """tau in [0, 0.25]^d, sigma in [gap+0.25, gap+0.5]^d — admissible."""
    tau = rng.uniform(0.0, 0.25, size=(b, m, d))
    sigma = rng.uniform(gap + 0.25, gap + 0.5, size=(b, n, d))
    return jnp.asarray(tau), jnp.asarray(sigma)


class TestDenseMv:
    @pytest.mark.parametrize("kernel", ["gaussian", "matern"])
    def test_matches_ref(self, kernel):
        rng = np.random.default_rng(2)
        tau = jnp.asarray(rng.uniform(size=(3, 64, 2)))
        sigma = jnp.asarray(rng.uniform(size=(3, 128, 2)))
        x = jnp.asarray(rng.uniform(-1, 1, size=(3, 128)))
        got = model.dense_mv(tau, sigma, x, kernel=kernel)
        want = ref.dense_mv_ref(tau, sigma, x, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11)

    def test_zero_x_gives_zero(self):
        rng = np.random.default_rng(3)
        tau = jnp.asarray(rng.uniform(size=(1, 64, 2)))
        x = jnp.zeros((1, 64))
        got = model.dense_mv(tau, tau, x)
        assert np.allclose(np.asarray(got), 0.0)

    def test_padded_columns_are_neutral(self):
        """Zero-padding x neutralizes padded sigma columns (§5.4.2)."""
        rng = np.random.default_rng(4)
        tau = jnp.asarray(rng.uniform(size=(1, 64, 2)))
        sigma_real = jnp.asarray(rng.uniform(size=(1, 64, 2)))
        x_real = jnp.asarray(rng.uniform(-1, 1, size=(1, 64)))
        # pad sigma to 128 with junk, x with zeros
        sigma_pad = jnp.concatenate([sigma_real, jnp.full((1, 64, 2), 7.7)], axis=1)
        x_pad = jnp.concatenate([x_real, jnp.zeros((1, 64))], axis=1)
        got = model.dense_mv(tau, sigma_pad, x_pad)
        want = model.dense_mv(tau, sigma_real, x_real)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


class TestAca:
    def test_factors_approximate_block(self):
        rng = np.random.default_rng(5)
        b, m, n, k = 2, 64, 64, 12
        tau, sigma = separated_clusters(rng, b, m, n, 2)
        rm = jnp.ones((b, m))
        cm = jnp.ones((b, n))
        u, v = model.aca_factors(tau, sigma, rm, cm, k=k)
        a = np.asarray(ref.assemble_ref(tau, sigma, "gaussian"))
        approx = np.einsum("bmk,bnk->bmn", np.asarray(u), np.asarray(v))
        err = np.linalg.norm(a - approx) / np.linalg.norm(a)
        assert err < 1e-8, err

    def test_rank_convergence(self):
        """Exponential convergence in k (Fig 11 in miniature)."""
        rng = np.random.default_rng(6)
        tau, sigma = separated_clusters(rng, 1, 128, 128, 2)
        rm = jnp.ones((1, 128))
        cm = jnp.ones((1, 128))
        a = np.asarray(ref.assemble_ref(tau, sigma, "gaussian"))
        errs = []
        for k in [1, 2, 4, 8]:
            u, v = model.aca_factors(tau, sigma, rm, cm, k=k)
            approx = np.einsum("bmk,bnk->bmn", np.asarray(u), np.asarray(v))
            errs.append(np.linalg.norm(a - approx) / np.linalg.norm(a))
        assert errs[1] < errs[0] and errs[2] < errs[1] and errs[3] < errs[2], errs
        assert errs[3] < 1e-6, errs

    def test_fused_mv_equals_factors_then_apply(self):
        rng = np.random.default_rng(7)
        b, m, n, k = 3, 64, 64, 8
        tau, sigma = separated_clusters(rng, b, m, n, 3)
        rm = jnp.ones((b, m))
        cm = jnp.ones((b, n))
        x = jnp.asarray(rng.uniform(-1, 1, size=(b, n)))
        y_fused = model.aca_mv(tau, sigma, x, rm, cm, k=k)
        u, v = model.aca_factors(tau, sigma, rm, cm, k=k)
        t = jnp.einsum("bnk,bn->bk", v, x)
        y_two = jnp.einsum("bmk,bk->bm", u, t)
        np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_two), rtol=1e-10)

    def test_padding_invariance(self):
        """Masked (padded) rows/cols and dummy batch entries must not change
        the valid outputs — the contract the Rust runtime relies on."""
        rng = np.random.default_rng(8)
        m_real, n_real, k = 48, 40, 6
        tau_r, sigma_r = separated_clusters(rng, 1, m_real, n_real, 2)
        x_r = jnp.asarray(rng.uniform(-1, 1, size=(1, n_real)))
        rm_r = jnp.ones((1, m_real))
        cm_r = jnp.ones((1, n_real))
        y_ref = model.aca_mv(tau_r, sigma_r, x_r, rm_r, cm_r, k=k)

        # pad rows/cols to 64 by replicating the first point, x with zeros,
        # masks with zeros; add a dummy all-masked batch entry of garbage
        def pad(arr, target, axis, fill):
            pad_n = target - arr.shape[axis]
            reps = jnp.repeat(fill, pad_n, axis=axis)
            return jnp.concatenate([arr, reps], axis=axis)

        tau_p = pad(tau_r, 64, 1, tau_r[:, :1])
        sigma_p = pad(sigma_r, 64, 1, sigma_r[:, :1])
        x_p = pad(x_r, 64, 1, jnp.zeros((1, 1)))
        rm_p = pad(rm_r, 64, 1, jnp.zeros((1, 1)))
        cm_p = pad(cm_r, 64, 1, jnp.zeros((1, 1)))
        # dummy second batch entry: all zeros points, masks zero
        tau_b = jnp.concatenate([tau_p, jnp.zeros_like(tau_p)], axis=0)
        sigma_b = jnp.concatenate([sigma_p, jnp.zeros_like(sigma_p)], axis=0)
        x_b = jnp.concatenate([x_p, jnp.zeros_like(x_p)], axis=0)
        rm_b = jnp.concatenate([rm_p, jnp.zeros_like(rm_p)], axis=0)
        cm_b = jnp.concatenate([cm_p, jnp.zeros_like(cm_p)], axis=0)

        y_pad = model.aca_mv(tau_b, sigma_b, x_b, rm_b, cm_b, k=k)
        y_pad = np.asarray(y_pad)
        np.testing.assert_allclose(y_pad[0, :m_real], np.asarray(y_ref)[0], rtol=1e-9, atol=1e-12)
        # padded rows and the dummy batch produce zeros / finite values
        assert np.all(np.isfinite(y_pad))
        np.testing.assert_allclose(y_pad[1], 0.0, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(8, 64),
        n=st.integers(8, 64),
        k=st.integers(1, 8),
        kernel=st.sampled_from(["gaussian", "matern"]),
    )
    def test_aca_error_bounded_hypothesis(self, m, n, k, kernel):
        """ACA approximation error is bounded by the (k+1)-th singular
        value's tail, and factors are always finite."""
        rng = np.random.default_rng(m * 100 + n * 10 + k)
        tau, sigma = separated_clusters(rng, 1, m, n, 2)
        rm = jnp.ones((1, m))
        cm = jnp.ones((1, n))
        u, v = model.aca_factors(tau, sigma, rm, cm, k=k, kernel=kernel)
        u, v = np.asarray(u), np.asarray(v)
        assert np.all(np.isfinite(u)) and np.all(np.isfinite(v))
        a = np.asarray(ref.assemble_ref(tau, sigma, kernel))[0]
        approx = u[0] @ v[0].T
        err = np.linalg.norm(a - approx)
        # SVD lower bound: best rank-k error
        svals = np.linalg.svd(a, compute_uv=False)
        best = np.linalg.norm(svals[k:])
        # ACA with partial pivoting is near-optimal on asymptotically smooth
        # kernels; allow a generous factor plus an absolute floor.
        assert err <= max(200.0 * best, 1e-10), (err, best)
