"""L1 tests: Pallas assembly kernel vs the pure-jnp oracle, plus the
kernel-function formulas vs scipy."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import assembly, ref

jax.config.update("jax_enable_x64", True)


def rand_points(rng, b, n, d, dtype=np.float64):
    return jnp.asarray(rng.uniform(0.0, 1.0, size=(b, n, d)).astype(dtype))


class TestPhi:
    def test_gaussian_values(self):
        r2 = jnp.asarray([0.0, 1.0, 4.0])
        np.testing.assert_allclose(ref.phi_r2(r2, "gaussian", 2), np.exp([-0.0, -1.0, -4.0]))

    def test_matern_k1_vs_scipy(self):
        scipy_special = pytest.importorskip("scipy.special")
        x = np.linspace(0.05, 10.0, 200)
        want = x * scipy_special.k1(x)
        got = np.asarray(ref.x_bessel_k1(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=5e-7, atol=1e-9)

    def test_matern_diagonal_limit(self):
        # x*K1(x) -> 1 as x -> 0; phi_M(0) = norm
        val = ref.phi_r2(jnp.asarray([0.0]), "matern", 2)
        np.testing.assert_allclose(val, [0.5], rtol=1e-12)

    def test_matern_norm_matches_rust_constants(self):
        # d=2 -> 0.5 ; d=3 -> 1/(2^1.5 * Gamma(2.5))
        assert abs(ref.matern_norm(2) - 0.5) < 1e-15
        assert abs(ref.matern_norm(3) - 1.0 / (2.0**1.5 * 1.3293403881791370)) < 1e-12

    def test_exponential(self):
        np.testing.assert_allclose(
            ref.phi_r2(jnp.asarray([4.0]), "exponential", 2), [np.exp(-2.0)]
        )

    def test_unknown_kernel_raises(self):
        with pytest.raises(ValueError):
            ref.phi_r2(jnp.asarray([1.0]), "bogus", 2)


class TestAssemblyKernel:
    @pytest.mark.parametrize("kernel", ["gaussian", "matern", "exponential"])
    @pytest.mark.parametrize("d", [2, 3])
    def test_matches_ref(self, kernel, d):
        rng = np.random.default_rng(0)
        tau = rand_points(rng, 2, 128, d)
        sigma = rand_points(rng, 2, 64, d)
        got = assembly.assemble(tau, sigma, kernel)
        want = ref.assemble_ref(tau, sigma, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-14)

    def test_symmetry_on_same_points(self):
        rng = np.random.default_rng(1)
        pts = rand_points(rng, 1, 64, 2)
        a = np.asarray(assembly.assemble(pts, pts, "gaussian"))[0]
        np.testing.assert_allclose(a, a.T, rtol=1e-13)
        np.testing.assert_allclose(np.diag(a), 1.0, rtol=1e-13)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4),
        mt=st.integers(1, 4),
        nt=st.integers(1, 4),
        d=st.integers(1, 4),
        kernel=st.sampled_from(["gaussian", "matern"]),
    )
    def test_shape_sweep_hypothesis(self, b, mt, nt, d, kernel):
        """Hypothesis sweep over grid shapes (tile multiples) and dims."""
        m, n = 64 * mt, 64 * nt
        rng = np.random.default_rng(b * 1000 + mt * 100 + nt * 10 + d)
        tau = rand_points(rng, b, m, d)
        sigma = rand_points(rng, b, n, d)
        got = assembly.assemble(tau, sigma, kernel)
        assert got.shape == (b, m, n)
        want = ref.assemble_ref(tau, sigma, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-13)

    def test_float32_supported(self):
        rng = np.random.default_rng(5)
        tau = rand_points(rng, 1, 64, 2, np.float32)
        sigma = rand_points(rng, 1, 64, 2, np.float32)
        got = assembly.assemble(tau, sigma, "gaussian")
        assert got.dtype == jnp.float32
        want = ref.assemble_ref(tau, sigma, "gaussian")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)

    def test_coincident_points_finite_for_matern(self):
        # r = 0 off-diagonal (duplicated points) must not produce inf/nan
        tau = jnp.zeros((1, 64, 2))
        a = np.asarray(assembly.assemble(tau, tau, "matern"))
        assert np.all(np.isfinite(a))
        np.testing.assert_allclose(a, 0.5, rtol=1e-12)
