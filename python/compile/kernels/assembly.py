"""L1 Pallas kernel: batched kernel-matrix tile assembly.

The compute hot-spot of the H-matrix method is evaluating phi on block
tiles (the paper's "evaluating matrix elements is often much faster [than
storing them]" observation drives the whole NP recompute strategy). This
kernel computes A[b, i, j] = phi(tau[b, i], sigma[b, j]) tile by tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): a (BM × BN) tile of A plus
the two point slabs (BM × D, BN × D) live in VMEM; the grid walks
(batch, M/BM, N/BN) so HBM→VMEM traffic is one slab read per tile row/col
and one tile write — the BlockSpec below *is* the paper's
threadblock-to-shared-memory schedule, re-expressed. The distance
computation is a rank-D contraction (MXU-friendly once D is padded) and
phi is elementwise on the VPU.

Must be lowered with interpret=True for CPU execution (Mosaic custom-calls
cannot run on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

jax.config.update("jax_enable_x64", True)

# Tile sizes: multiples of the smallest bucket (64); 64×64 f64 tiles are
# 32 KiB — three buffers fit comfortably in a 16 MiB VMEM budget.
TILE_M = 64
TILE_N = 64


def _phi_tile(tau_tile, sigma_tile, kernel: str, d: int):
    """phi on a (BM, D) x (BN, D) tile -> (BM, BN)."""
    diff = tau_tile[:, None, :] - sigma_tile[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    return ref.phi_r2(r2, kernel, d)


def _assembly_kernel(tau_ref, sigma_ref, out_ref, *, kernel: str, d: int):
    """Pallas body: one (TILE_M, TILE_N) tile of one batch element."""
    tau_tile = tau_ref[0]  # [TILE_M, D]
    sigma_tile = sigma_ref[0]  # [TILE_N, D]
    out_ref[0] = _phi_tile(tau_tile, sigma_tile, kernel, d)


@functools.partial(jax.jit, static_argnames=("kernel",))
def assemble(tau, sigma, kernel: str = "gaussian"):
    """Batched assembly A[b,i,j] = phi(tau[b,i], sigma[b,j]) via Pallas.

    tau: [B, M, D], sigma: [B, N, D] -> [B, M, N]; M, N must be multiples
    of the tile sizes (the AOT buckets are).
    """
    b, m, d = tau.shape
    _, n, _ = sigma.shape
    tile_m = min(TILE_M, m)
    tile_n = min(TILE_N, n)
    assert m % tile_m == 0 and n % tile_n == 0, (m, n)
    grid = (b, m // tile_m, n // tile_n)
    return pl.pallas_call(
        functools.partial(_assembly_kernel, kernel=kernel, d=d),
        out_shape=jax.ShapeDtypeStruct((b, m, n), tau.dtype),
        grid=grid,
        in_specs=[
            # each grid step sees one batch element's tile row slab ...
            pl.BlockSpec((1, tile_m, d), lambda bi, i, j: (bi, i, 0)),
            # ... and tile column slab
            pl.BlockSpec((1, tile_n, d), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_m, tile_n), lambda bi, i, j: (bi, i, j)),
        interpret=True,
    )(tau, sigma)
