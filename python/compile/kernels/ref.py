"""Pure-jnp reference oracles for the L1/L2 numerics.

Everything here is the *specification*: the Pallas assembly kernel and the
batched ACA graph are tested against these functions, and the Rust native
engine implements the same formulas (identical Abramowitz & Stegun
coefficients), so all three layers agree to ~1e-8.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# --- kernel functions phi (must mirror rust/src/geometry/{bessel,kernel}.rs) ---

# A&S 9.8.3: I1(x)/x for |x| <= 3.75
_I1_COEFFS = (0.5, 0.87890594, 0.51498869, 0.15084934, 0.02658733, 0.00301532, 0.00032411)
# A&S 9.8.7 polynomial part of x*K1(x), x <= 2
_K1_SMALL = (1.0, 0.15443144, -0.67278579, -0.18156897, -0.01919402, -0.00110404, -0.00004686)
# A&S 9.8.8: sqrt(x) e^x K1(x), x >= 2
_K1_LARGE = (1.25331414, 0.23498619, -0.03655620, 0.01504268, -0.00780353, 0.00325614, -0.00068245)


def _poly(coeffs, t):
    acc = jnp.zeros_like(t) + coeffs[-1]
    for c in reversed(coeffs[:-1]):
        acc = acc * t + c
    return acc


def bessel_i1_small(x):
    """I1(x) for |x| <= 3.75 (A&S 9.8.3)."""
    t2 = (x / 3.75) ** 2
    return x * _poly(_I1_COEFFS, t2)


def x_bessel_k1(x):
    """x*K1(x), continuously extended by 1 at x = 0."""
    xs = jnp.maximum(x, 1e-12)
    small = xs * jnp.log(xs / 2.0) * bessel_i1_small(xs) + _poly(_K1_SMALL, (xs / 2.0) ** 2)
    large = xs * _poly(_K1_LARGE, 2.0 / xs) * jnp.exp(-xs) / jnp.sqrt(xs)
    val = jnp.where(xs <= 2.0, small, large)
    return jnp.where(x < 1e-12, 1.0, val)


_SQRT_PI = 1.7724538509055159


def _gamma_one_plus_half_d(d: int) -> float:
    """Gamma(1 + d/2) for integer d (exact recurrence)."""
    two_beta = 2 + d
    if two_beta % 2 == 0:
        m = two_beta // 2
        out = 1.0
        for kk in range(1, m):
            out *= float(kk)
        return out
    n = (two_beta - 1) // 2
    acc = _SQRT_PI
    for kk in range(n):
        acc *= 0.5 + kk
    return acc


def matern_norm(d: int) -> float:
    """1 / (2^{beta-1} Gamma(beta)) with beta = 1 + d/2."""
    beta = 1.0 + d / 2.0
    return 1.0 / (2.0 ** (beta - 1.0) * _gamma_one_plus_half_d(d))


def phi_r2(r2, kernel: str, d: int):
    """Evaluate phi from squared distances (elementwise)."""
    if kernel == "gaussian":
        return jnp.exp(-r2)
    if kernel == "matern":
        return matern_norm(d) * x_bessel_k1(jnp.sqrt(r2))
    if kernel == "exponential":
        return jnp.exp(-jnp.sqrt(r2))
    raise ValueError(f"unknown kernel {kernel}")


# --- reference batched operations ---


def assemble_ref(tau, sigma, kernel: str):
    """Batched kernel-matrix assembly: A[b,i,j] = phi(tau[b,i], sigma[b,j]).

    tau: [B, M, D], sigma: [B, N, D] -> [B, M, N].
    """
    diff = tau[:, :, None, :] - sigma[:, None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    return phi_r2(r2, kernel, tau.shape[-1])


def dense_mv_ref(tau, sigma, x, kernel: str):
    """Batched dense mat-vec: y[b] = A_b @ x[b]."""
    a = assemble_ref(tau, sigma, kernel)
    return jnp.einsum("bmn,bn->bm", a, x)


def aca_factors_block_ref(tau, sigma, row_mask, col_mask, k: int, kernel: str):
    """Fixed-rank ACA with partial pivoting for ONE block (M,D)/(N,D).

    Mirrors rust/src/aca/seq.rs `aca_fixed_rank` (same pivot rules:
    first-occurrence argmax, used-row/col exclusion, 1e-14 pivot floor).
    Returns U [M, k], V [N, k] with A ~= U @ V.T (masked entries zero).
    """
    d = tau.shape[-1]

    def phi_col(j):
        diff = tau - sigma[j][None, :]
        return phi_r2(jnp.sum(diff * diff, axis=-1), kernel, d)

    def phi_row(i):
        diff = sigma - tau[i][None, :]
        return phi_r2(jnp.sum(diff * diff, axis=-1), kernel, d)

    def body(r, carry):
        u_mat, v_mat, used_r, used_c, j_cur = carry
        rank_mask = (jnp.arange(k) < r).astype(u_mat.dtype)
        # residual column
        u_hat = phi_col(j_cur) - u_mat @ (v_mat[j_cur] * rank_mask)
        u_hat = jnp.where(row_mask > 0, u_hat, 0.0)
        scores = jnp.where(used_r, -1.0, jnp.abs(u_hat))
        i_cur = jnp.argmax(scores)
        best = scores[i_cur]
        active = best > 1e-14
        pivot = u_hat[i_cur]
        pivot = jnp.where(jnp.abs(pivot) < 1e-300, 1.0, pivot)
        u_r = jnp.where(active, u_hat / pivot, 0.0)
        # residual row
        v_r = phi_row(i_cur) - v_mat @ (u_mat[i_cur] * rank_mask)
        v_r = jnp.where(col_mask > 0, v_r, 0.0)
        v_r = jnp.where(active, v_r, 0.0)
        u_mat = u_mat.at[:, r].set(u_r)
        v_mat = v_mat.at[:, r].set(v_r)
        used_r = jnp.where(active, used_r.at[i_cur].set(True), used_r)
        # the current column is retired either way: accepted as a pivot, or
        # found to have zero residual (e.g. a duplicate of a used column —
        # zero residual does NOT mean the block is exhausted)
        used_c = used_c.at[j_cur].set(True)
        cscores = jnp.where(used_c, -1.0, jnp.abs(v_r))
        j_next = jnp.argmax(cscores)
        # on pivot failure: advance to the first unused column instead
        # (mirrors the column-retry of the sequential/native batched ACA)
        first_unused = jnp.argmax(~used_c)
        j_cur = jnp.where(active, j_next, first_unused)
        return u_mat, v_mat, used_r, used_c, j_cur

    m_pts, n_pts = tau.shape[0], sigma.shape[0]
    u0 = jnp.zeros((m_pts, k))
    v0 = jnp.zeros((n_pts, k))
    used_r0 = row_mask <= 0  # padded rows start "used"
    used_c0 = col_mask <= 0
    j0 = jnp.argmax(col_mask)  # first valid column
    u_mat, v_mat, _, _, _ = jax.lax.fori_loop(0, k, body, (u0, v0, used_r0, used_c0, j0))
    return u_mat, v_mat


def aca_factors_ref(tau, sigma, row_mask, col_mask, k: int, kernel: str):
    """Batched fixed-rank ACA factors: vmap of the single-block reference."""
    return jax.vmap(lambda t, s, rm, cm: aca_factors_block_ref(t, s, rm, cm, k, kernel))(
        tau, sigma, row_mask, col_mask
    )


def aca_mv_ref(tau, sigma, x, row_mask, col_mask, k: int, kernel: str):
    """Fused batched ACA + low-rank apply: y[b] = U_b (V_b^T x[b])."""
    u_mat, v_mat = aca_factors_ref(tau, sigma, row_mask, col_mask, k, kernel)
    t = jnp.einsum("bnk,bn->bk", v_mat, x)
    return jnp.einsum("bmk,bk->bm", u_mat, t)
