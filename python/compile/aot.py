"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.

Emits one HLO text file per (op, kernel, d, bucket) signature plus
`manifest.tsv` (see rust/src/runtime/artifacts.rs for the schema).

HLO *text* (never `.serialize()`): jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md and aot_recipe).

Usage:  cd python && python -m compile.aot --out ../artifacts \
            [--kernels gaussian,matern] [--dims 2,3] [--k 16] \
            [--dense-buckets 64,256] [--aca-buckets 256,512,1024] [--batch 16] \
            [--rhs-widths 4,16]

`--rhs-widths` additionally emits fused multi-RHS `dense_mm`/`aca_mm`
artifacts at those fixed widths (the serving width-ladder rungs; manifest
column `r`). Single-RHS rows carry `r = 1`.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned on parse)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def lower_dense_mv(kernel: str, d: int, m: int, b: int):
    fn = lambda tau, sigma, x: model.dense_mv(tau, sigma, x, kernel=kernel)
    return jax.jit(fn).lower(spec(b, m, d), spec(b, m, d), spec(b, m))


def lower_aca_mv(kernel: str, d: int, m: int, k: int, b: int):
    fn = lambda tau, sigma, x, rm, cm: model.aca_mv(tau, sigma, x, rm, cm, k=k, kernel=kernel)
    return jax.jit(fn).lower(spec(b, m, d), spec(b, m, d), spec(b, m), spec(b, m), spec(b, m))


def lower_aca_factors(kernel: str, d: int, m: int, k: int, b: int):
    fn = lambda tau, sigma, rm, cm: model.aca_factors(tau, sigma, rm, cm, k=k, kernel=kernel)
    return jax.jit(fn).lower(spec(b, m, d), spec(b, m, d), spec(b, m), spec(b, m))


def lower_dense_mm(kernel: str, d: int, m: int, b: int, r: int):
    fn = lambda tau, sigma, x: model.dense_mm(tau, sigma, x, kernel=kernel)
    return jax.jit(fn).lower(spec(b, m, d), spec(b, m, d), spec(b, m, r))


def lower_aca_mm(kernel: str, d: int, m: int, k: int, b: int, r: int):
    fn = lambda tau, sigma, x, rm, cm: model.aca_mm(tau, sigma, x, rm, cm, k=k, kernel=kernel)
    return jax.jit(fn).lower(
        spec(b, m, d), spec(b, m, d), spec(b, m, r), spec(b, m), spec(b, m)
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--kernels", default="gaussian,matern")
    ap.add_argument("--dims", default="2,3")
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--dense-buckets", default="64,256")
    ap.add_argument("--aca-buckets", default="256,512,1024")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--rhs-widths", default="4,16")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    kernels = [k for k in args.kernels.split(",") if k]
    dims = [int(x) for x in args.dims.split(",") if x]
    dense_buckets = [int(x) for x in args.dense_buckets.split(",") if x]
    aca_buckets = [int(x) for x in args.aca_buckets.split(",") if x]
    rhs_widths = [int(x) for x in args.rhs_widths.split(",") if x]
    b = args.batch
    k = args.k

    rows = []

    def emit(name, lowered, op, kernel, d, m, n, kk, r=1):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rows.append((name, fname, op, kernel, d, m, n, kk, b, r))
        print(f"  wrote {fname} ({len(text) // 1024} KiB)")

    for kernel in kernels:
        for d in dims:
            for m in dense_buckets:
                name = f"dense_mv_{kernel}_d{d}_m{m}"
                print(f"lowering {name} ...")
                emit(name, lower_dense_mv(kernel, d, m, b), "dense_mv", kernel, d, m, m, 0)
                for r in rhs_widths:
                    name = f"dense_mm_{kernel}_d{d}_m{m}_r{r}"
                    print(f"lowering {name} ...")
                    emit(
                        name,
                        lower_dense_mm(kernel, d, m, b, r),
                        "dense_mm",
                        kernel,
                        d,
                        m,
                        m,
                        0,
                        r,
                    )
            for m in aca_buckets:
                name = f"aca_mv_{kernel}_d{d}_m{m}_k{k}"
                print(f"lowering {name} ...")
                emit(name, lower_aca_mv(kernel, d, m, k, b), "aca_mv", kernel, d, m, m, k)
                for r in rhs_widths:
                    name = f"aca_mm_{kernel}_d{d}_m{m}_k{k}_r{r}"
                    print(f"lowering {name} ...")
                    emit(
                        name,
                        lower_aca_mm(kernel, d, m, k, b, r),
                        "aca_mm",
                        kernel,
                        d,
                        m,
                        m,
                        k,
                        r,
                    )
                name = f"aca_factors_{kernel}_d{d}_m{m}_k{k}"
                print(f"lowering {name} ...")
                emit(
                    name,
                    lower_aca_factors(kernel, d, m, k, b),
                    "aca_factors",
                    kernel,
                    d,
                    m,
                    m,
                    k,
                )

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# name\tfile\top\tkernel\td\tm\tn\tk\tb\tr\n")
        for row in rows:
            f.write("\t".join(str(c) for c in row) + "\n")
    print(f"wrote {manifest} with {len(rows)} artifacts")


if __name__ == "__main__":
    main()
