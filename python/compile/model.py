"""L2: the batched linear-algebra compute graphs (§5.4 of the paper).

Five entry points, each AOT-lowered per shape bucket by `aot.py`:

* `dense_mv`      — batched dense block mat-vec: Pallas-assembled tiles
                    (L1) contracted against x (the paper's MAGMA
                    `dgemv_vbatched` role).
* `aca_mv`        — fused batched fixed-rank ACA + low-rank apply
                    (NP mode: factors live only inside the executable).
* `aca_factors`   — batched ACA factors only (P-mode precompute).
* `dense_mm`      — multi-RHS `dense_mv`: one assembly amortized over a
                    fixed RHS width R (the serving width-ladder rungs).
* `aca_mm`        — multi-RHS fused ACA + low-rank apply at width R.

The ACA iteration itself is data-dependent gather/argmax-heavy work, which
stays at the JAX level (vmap of a fori_loop); its inner kernel evaluations
are the same formulas the L1 assembly kernel uses.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import assembly, ref

jax.config.update("jax_enable_x64", True)


@functools.partial(jax.jit, static_argnames=("kernel",))
def dense_mv(tau, sigma, x, kernel: str = "gaussian"):
    """y[b] = A_b x[b] with A_b assembled on the fly by the Pallas kernel.

    tau: [B, M, D], sigma: [B, N, D], x: [B, N] -> y: [B, M].
    Padded sigma columns are neutralized by zeroed x entries (phi stays
    finite on padded points by construction).
    """
    a = assembly.assemble(tau, sigma, kernel)
    return jnp.einsum("bmn,bn->bm", a, x)


@functools.partial(jax.jit, static_argnames=("k", "kernel"))
def aca_mv(tau, sigma, x, row_mask, col_mask, k: int = 16, kernel: str = "gaussian"):
    """Fused batched rank-k ACA + apply; see ref.aca_mv_ref (the oracle is
    the implementation here — the ACA graph is already the batched
    formulation)."""
    return ref.aca_mv_ref(tau, sigma, x, row_mask, col_mask, k, kernel)


@functools.partial(jax.jit, static_argnames=("k", "kernel"))
def aca_factors(tau, sigma, row_mask, col_mask, k: int = 16, kernel: str = "gaussian"):
    """Batched rank-k ACA factors (U [B,M,K], V [B,N,K])."""
    return ref.aca_factors_ref(tau, sigma, row_mask, col_mask, k, kernel)


@functools.partial(jax.jit, static_argnames=("kernel",))
def dense_mm(tau, sigma, x, kernel: str = "gaussian"):
    """Multi-RHS dense_mv: one on-the-fly assembly applied to R columns.

    tau: [B, M, D], sigma: [B, N, D], x: [B, N, R] -> y: [B, M, R].
    The serving batcher pads flushes to the fixed widths this is lowered
    at, so assembly cost is amortized over the whole flush instead of
    being re-paid per column.
    """
    a = assembly.assemble(tau, sigma, kernel)
    return jnp.einsum("bmn,bnr->bmr", a, x)


@functools.partial(jax.jit, static_argnames=("k", "kernel"))
def aca_mm(tau, sigma, x, row_mask, col_mask, k: int = 16, kernel: str = "gaussian"):
    """Multi-RHS fused rank-k ACA + low-rank apply.

    x: [B, N, R] -> y: [B, M, R]. The ACA sweep runs ONCE per block and
    both contraction stages carry all R columns: y = U (V^T x).
    """
    u, v = ref.aca_factors_ref(tau, sigma, row_mask, col_mask, k, kernel)
    vt_x = jnp.einsum("bnk,bnr->bkr", v, x)
    return jnp.einsum("bmk,bkr->bmr", u, vt_x) * row_mask[:, :, None]
